"""Quickstart: the paper's programming model in five minutes.

Builds a compound multi-kernel computation (a Marrow skeleton
computational tree), hands it to the scheduler, and lets the runtime
decompose it locality-aware across the available execution resources,
derive a workload distribution from the knowledge base, and refine it
online — exactly the Fig. 4 decision workflow.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (AcceleratorPlatform, DeviceInfo, HostPlatform,
                        JobGraph, KnowledgeBase, Pipeline, Scheduler,
                        Session, ThreadedExecutor, kernel, scalar, vector)


def main():
    # 1. Wrap kernels with their interfaces (paper Table 1): scale and
    #    shift share the vector edge "mid" -> the locality-aware
    #    decomposition partitions both identically, so "mid" never moves.
    scale = kernel(lambda a, x: a * x, name="scale",
                   inputs=[scalar("a"), vector("x")],
                   outputs=[vector("mid")])
    shift = kernel(lambda m, b: m + b, name="shift",
                   inputs=[vector("mid"), scalar("b")],
                   outputs=[vector("y")])
    sct = Pipeline(scale, shift)
    print("SCT:", sct.unique_id())

    # 2. Describe the execution resources (host CPU + accelerator class).
    host = HostPlatform(DeviceInfo("cpu0", "cpu", compute_units=8),
                        topology={"L1": 8, "L2": 4, "L3": 2,
                                  "NO_FISSION": 1})
    accel = AcceleratorPlatform([DeviceInfo("acc0", "gpu")], max_overlap=4)

    # 3. Scheduler = KB-derived distribution + lbt monitor + adaptive
    #    rebalancing; Session = the async FCFS request queue.
    sched = Scheduler(host=host, accel=accel, executor=ThreadedExecutor(),
                      kb=KnowledgeBase())
    session = Session(sched)

    x = np.arange(1 << 16, dtype=np.float32)
    fut = session.run(sct, a=np.float32(2.0), b=np.float32(1.0), x=x)
    run = fut.get()
    np.testing.assert_allclose(run.outputs["y"], 2 * x + 1)
    print(f"run 1: action={run.action} share_a={run.profile.share_a:.2f} "
          f"partitions={len(run.stats.times)}")

    # 4. Recurrent executions reuse (and refine) the stored profile.
    for i in range(3):
        run = session.run(sct, a=np.float32(2.0), b=np.float32(1.0),
                          x=x).get()
        print(f"run {i + 2}: action={run.action} "
              f"deviation={run.stats.deviation:.2f}")

    # 5. A new workload size triggers KB derivation (Sec. 3.2.3).
    x2 = np.arange(1 << 18, dtype=np.float32)
    run = session.run(sct, a=np.float32(3.0), b=np.float32(0.5),
                      x=x2).get()
    np.testing.assert_allclose(run.outputs["y"], 3 * x2 + 0.5)
    print(f"new workload: action={run.action} (KB size={len(sched.kb)})")

    # 6. Fan-out: independent computations as one JobGraph — nodes with
    #    no mutual dependencies overlap on the per-device work queues
    #    (docs/architecture.md).
    square = kernel(lambda x: x * x, name="square",
                    inputs=[vector("x")], outputs=[vector("sq")])
    negate = kernel(lambda x: -x, name="negate",
                    inputs=[vector("x")], outputs=[vector("neg")])
    g = JobGraph()
    g.add(square)
    g.add(negate)
    g.add(sct)                       # the pipeline rides along too
    handle = session.submit(g, a=np.float32(2.0), b=np.float32(1.0), x=x)
    result = handle.result(timeout=60)
    np.testing.assert_allclose(result.outputs["sq"], x * x)
    np.testing.assert_allclose(result.outputs["neg"], -x)
    np.testing.assert_allclose(result.outputs["y"], 2 * x + 1)
    print(f"graph fan-out: {len(result.order)} nodes, "
          f"states={set(handle.status().values())}")
    session.shutdown()
    print("quickstart OK")


if __name__ == "__main__":
    main()
