"""End-to-end training driver: a ~100M-parameter dense LM for a few
hundred steps on this host, with checkpoints, WSD/cosine schedules and
deterministic restart-safe data.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(The identical code path scales to the production mesh — the launcher
is `python -m repro.launch.train --arch <id>`; this example pins a
~100M config so it finishes on CPU.)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, batch_at
from repro.models import ModelConfig, init_tree, model_defs
from repro.optim import AdamW, AdamWConfig, cosine_schedule
from repro.runtime import RuntimeConfig, init_state, make_train_step


def config_100m() -> ModelConfig:
    """~100M params: 16L, d=672, llama-style dense."""
    return ModelConfig(arch="demo-100m", family="dense", n_layers=16,
                       d_model=672, n_heads=8, n_kv_heads=4, d_ff=1920,
                       vocab=16384, head_dim=84, tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/marrowtpu_100m")
    args = ap.parse_args()

    cfg = config_100m()
    print(f"[example] {cfg.arch}: {cfg.param_count() / 1e6:.1f}M params")
    opt = AdamW(AdamWConfig(lr=cosine_schedule(3e-3, warmup=20,
                                               total=args.steps)))
    params = init_tree(jax.random.PRNGKey(0), model_defs(cfg))
    state = init_state(params, opt)
    step_fn = jax.jit(make_train_step(
        cfg, opt, RuntimeConfig(microbatches=2, remat="dots",
                                loss_chunks=4)), donate_argnums=(0,))
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                    global_batch=args.batch)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    start = 0
    got = mgr.restore_latest(jax.device_get(state))
    if got is not None:
        state = jax.tree.map(jnp.asarray, got[0])
        start = got[1].step
        print(f"[example] resumed from step {start}")

    t0 = time.time()
    first_loss = None
    for step in range(start, args.steps):
        state, metrics = step_fn(state, batch_at(dc, step))
        if step % 25 == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            first_loss = first_loss if first_loss is not None else loss
            tps = (args.batch * args.seq_len * (step + 1 - start)
                   / max(time.time() - t0, 1e-9))
            print(f"step {step:4d} loss={loss:.4f} "
                  f"lr={float(metrics['lr']):.2e} tok/s={tps:,.0f}")
        if (step + 1) % 100 == 0:
            mgr.save(step + 1, state)
    mgr.save(args.steps, state, blocking=True)
    final = float(metrics["loss"])
    print(f"[example] loss {first_loss:.3f} -> {final:.3f} "
          f"in {time.time() - t0:.0f}s")
    if args.steps - start >= 200:      # short smoke runs are noise-bound
        assert final < first_loss, "training must reduce the loss"


if __name__ == "__main__":
    main()
