"""Batched serving example: continuous slot-based batching with mixed
request lengths over a smoke-scale hybrid (Mamba2+attention) model.

    PYTHONPATH=src python examples/serve_llm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models import init_tree, model_defs
from repro.runtime import ServeEngine


def main():
    cfg = get_smoke("zamba2-2.7b")
    print(f"[serve] {cfg.arch} ({cfg.param_count() / 1e6:.2f}M params)")
    params = init_tree(jax.random.PRNGKey(0), model_defs(cfg))
    engine = ServeEngine(cfg, params, slots=4, capacity=96,
                         temperature=0.8, seed=0)

    rng = np.random.default_rng(0)
    # a first wave of requests...
    for _ in range(6):
        plen = int(rng.integers(4, 24))
        engine.submit(rng.integers(0, cfg.vocab, plen).tolist(),
                      max_new=int(rng.integers(6, 20)))
    t0 = time.time()
    steps = 0
    late_submitted = False
    while engine.queue or any(s is not None for s in engine.active):
        engine.step()
        steps += 1
        # ...and a second wave arriving mid-flight (continuous batching)
        if steps == 5 and not late_submitted:
            for _ in range(3):
                engine.submit(rng.integers(0, cfg.vocab, 8).tolist(),
                              max_new=8)
            late_submitted = True
            print(f"[serve] 3 more requests joined at step {steps}")
        if steps > 5000:
            raise RuntimeError("did not converge")
    dt = time.time() - t0
    toks = sum(len(r.out) for r in engine.finished)
    print(f"[serve] {len(engine.finished)} requests -> {toks} tokens "
          f"in {dt:.1f}s ({toks / dt:.1f} tok/s, {steps} steps)")
    assert len(engine.finished) == 9
    assert all(len(r.out) == r.max_new for r in engine.finished)
    print("serve example OK")


if __name__ == "__main__":
    main()
