"""Checkpoint store — the fault-tolerance substrate.

Large-scale requirements implemented here:

* **Atomicity** — a checkpoint directory is written under a ``.tmp``
  name and ``os.replace``d into place only after every array file and the
  manifest have been fsync'd; a crash mid-write can never produce a
  half-readable "latest" step.
* **Async** — ``save()`` snapshots the pytree to host memory
  (``jax.device_get``) and hands the serialisation to a background
  thread; the train loop blocks only for the device->host copy.  The
  previous in-flight save is joined first (at most one outstanding).
* **Keep-K GC** — old steps beyond ``keep`` are deleted after a
  successful commit, never before.
* **Elastic / preemption restore** — ``restore_latest`` scans for the
  newest *committed* step, validates the manifest, and returns plain
  host arrays + metadata; the caller re-shards onto whatever mesh the
  restarted job has (device count may differ — arrays are stored in
  global logical shape).  Corrupt/partial directories are skipped, not
  fatal.
* **Multi-host** — on a real pod each process saves only the shards it
  owns (``process_index`` namespacing is built into the layout); on this
  single-process container that is one shard directory.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class CheckpointMeta:
    step: int
    payload: Dict[str, Any] = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# Flat (de)serialisation of pytrees
# ---------------------------------------------------------------------------

def _flatten(tree: Any) -> Tuple[Dict[str, np.ndarray], Any]:
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat: Dict[str, np.ndarray] = {}
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat, treedef


#: numpy cannot serialise accelerator dtypes — store them as raw bits
_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8, "float8_e4m3b11fnuz": np.uint8}


def save_pytree(tree: Any, directory: str) -> None:
    """Write one pytree as an .npz + structure manifest (not atomic alone)."""
    flat, treedef = _flatten(tree)
    os.makedirs(directory, exist_ok=True)
    payload, dtypes = {}, {}
    for k, v in flat.items():
        name = str(v.dtype)
        if name in _BITCAST:
            dtypes[k] = name
            v = v.view(_BITCAST[name])
        payload[k.replace("/", "|")] = v
    np.savez(os.path.join(directory, "arrays.npz"), **payload)
    with open(os.path.join(directory, "structure.json"), "w") as f:
        json.dump({"keys": list(flat.keys()), "dtypes": dtypes}, f)


def load_pytree(directory: str, like: Any) -> Any:
    """Load into the structure of ``like`` (shapes may be re-sharded later)."""
    with np.load(os.path.join(directory, "arrays.npz")) as z:
        flat = {k.replace("|", "/"): z[k] for k in z.files}
    with open(os.path.join(directory, "structure.json")) as f:
        dtypes = json.load(f).get("dtypes", {})
    import ml_dtypes
    for k, name in dtypes.items():
        flat[k] = flat[k].view(np.dtype(getattr(ml_dtypes, name)))
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf '{key}'")
        arr = flat[key]
        want = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"leaf '{key}': checkpoint shape {arr.shape} "
                             f"!= expected {want}")
        if hasattr(leaf, "dtype") and arr.dtype != np.asarray(leaf).dtype:
            arr = arr.astype(np.asarray(leaf).dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Manager
# ---------------------------------------------------------------------------

class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 3,
                 process_index: Optional[int] = None):
        self.root = root
        self.keep = keep
        self.process = (jax.process_index() if process_index is None
                        else process_index)
        os.makedirs(root, exist_ok=True)
        self._inflight: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    # -- paths -----------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:012d}")

    def _commit_marker(self, step_dir: str) -> str:
        return os.path.join(step_dir, "COMMITTED")

    # -- save --------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, payload: Optional[Dict] = None,
             blocking: bool = False) -> None:
        """Snapshot + async write.  ``payload``: small JSON metadata
        (data cursor, config hash, rng state...)."""
        self.wait()                                  # <=1 outstanding save
        host_tree = jax.device_get(tree)             # sync point (fast)
        meta = CheckpointMeta(step=step, payload=payload or {})

        def work():
            self._write(step, host_tree, meta)

        if blocking:
            work()
        else:
            t = threading.Thread(target=work, daemon=True,
                                 name=f"ckpt-save-{step}")
            t.start()
            with self._lock:
                self._inflight = t

    def wait(self) -> None:
        with self._lock:
            t = self._inflight
            self._inflight = None
        if t is not None:
            t.join()

    def _write(self, step: int, host_tree: Any, meta: CheckpointMeta) -> None:
        final = self._step_dir(step)
        parent = os.path.dirname(final)
        tmp = tempfile.mkdtemp(dir=parent, prefix=f".tmp_step{step}_")
        try:
            shard_dir = os.path.join(tmp, f"proc{self.process:05d}")
            save_pytree(host_tree, shard_dir)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({"step": meta.step, "payload": meta.payload,
                           "process_count": 1}, f)
                f.flush()
                os.fsync(f.fileno())
            open(self._commit_marker(tmp), "w").close()
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()

    # -- restore --------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and os.path.exists(
                    self._commit_marker(os.path.join(self.root, name))):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def restore_latest(self, like: Any
                       ) -> Optional[Tuple[Any, CheckpointMeta]]:
        """Newest committed checkpoint, or None.  Corrupt dirs are skipped."""
        for step in reversed(self.steps()):
            try:
                return self.restore(step, like)
            except (KeyError, ValueError, OSError, json.JSONDecodeError):
                continue
        return None

    def restore(self, step: int, like: Any) -> Tuple[Any, CheckpointMeta]:
        d = self._step_dir(step)
        with open(os.path.join(d, "meta.json")) as f:
            m = json.load(f)
        tree = load_pytree(os.path.join(d, f"proc{self.process:05d}"), like)
        return tree, CheckpointMeta(step=m["step"], payload=m["payload"])

    # -- GC ----------------------------------------------------------------------
    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
