"""Fault-tolerant checkpointing: atomic, async, keep-K, elastic restore."""
from repro.checkpoint.store import (CheckpointManager, CheckpointMeta,
                                    load_pytree, save_pytree)

__all__ = [n for n in dir() if not n.startswith("_")]
