"""Train-step builder: microbatching, remat, sharding, compression.

``make_train_step`` assembles the jitted step for any assigned
architecture from the runtime knobs the autotuner searches over
(EXPERIMENTS.md §Perf):

  * ``microbatches``  — gradient accumulation via ``lax.scan`` over batch
    slices.  This is the paper's GPU *overlap factor* mapped to TPU: with
    M in-flight microbatches XLA overlaps microbatch k's gradient
    collectives with microbatch k+1's compute (latency hiding), and the
    per-step activation footprint divides by M.
  * ``remat``         — activation-checkpoint policy on the scanned layer
    body ("none" | "dots" | "dots_no_batch" | "full").
  * ``loss_chunks``   — seq-chunked unembed+loss (never materialise B,S,V).

``make_dp_train_step_int8`` is the explicit-collective data-parallel
variant: the gradient sync runs inside ``shard_map`` with int8 + error
feedback on the wire (4x fewer collective bytes — the beyond-paper
collective-term reducer of §Perf).

Both steps are pure ``(state, batch) -> (state, metrics)`` and donate-safe
on ``state``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.lm import forward_backbone
from repro.optim.adamw import AdamW, OptState
from repro.optim.compress import (CompressionState, compress_gradients,
                                  decompress_sum, init_compression,
                                  shared_scale)
from repro.runtime.loss import chunked_xent

REMAT_POLICIES: Dict[Optional[str], Any] = {
    None: None,
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    compression: Optional[CompressionState] = None


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """The runtime knobs — one point of the §Perf search space."""

    microbatches: int = 1
    remat: Optional[str] = "dots_no_batch"
    remat_group: int = 1               # checkpoint every k layers
    remat_inner: Optional[str] = None  # per-layer policy inside a group
                                       # (None = same as ``remat``)
    loss_chunks: int = 1
    aux_weight: float = 0.01           # MoE load-balance loss weight
    data_axes: Tuple[str, ...] = ("data",)   # axes the batch is sharded over
    act_spec: Any = None               # PartitionSpec pinned on the residual
                                       # stream at every layer (see lm.py)


def init_state(params: Any, optimizer: AdamW, *,
               compress: bool = False) -> TrainState:
    return TrainState(params=params, opt=optimizer.init(params),
                      compression=init_compression(params) if compress
                      else None)


def make_loss_fn(cfg: ModelConfig, rt: RuntimeConfig):
    def loss_fn(params, tokens, labels, extras):
        x, aux = forward_backbone(
            params, cfg, tokens,
            remat_policy=REMAT_POLICIES[rt.remat],
            act_spec=rt.act_spec, remat_group=rt.remat_group,
            remat_inner_policy=REMAT_POLICIES[rt.remat_inner],
            **extras)
        tot, cnt = chunked_xent(x, params, cfg, labels,
                                chunks=rt.loss_chunks)
        loss = tot / jnp.maximum(cnt, 1.0)
        return loss + rt.aux_weight * aux, (loss, aux)

    return loss_fn


def _accumulate_grads(loss_fn, params, batch: Dict[str, jax.Array],
                      rt: RuntimeConfig):
    """Gradient accumulation over microbatches (scan => activations are
    per-microbatch; XLA pipelines collective/compute across iterations).

    The batch is *reshaped* to (M, B/M, ...) and consumed as the scan's
    xs — never dynamically sliced along the sharded batch dim, which
    would force an all-gather of the whole batch on every microbatch.
    The per-microbatch batch dim keeps the data-axis sharding via an
    explicit constraint (PartitionSpec-only form, mesh from context).
    """
    tokens, labels = batch["tokens"], batch["labels"]
    extras = {k: v for k, v in batch.items()
              if k not in ("tokens", "labels")}
    M = rt.microbatches
    B = tokens.shape[0]
    if M <= 1 or B % M:
        (_, (loss, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, tokens, labels, extras)
        return grads, loss, aux

    def to_mb(v):
        r = v.reshape((M, B // M) + v.shape[1:])
        spec = P(None, rt.data_axes) if rt.data_axes else P()
        try:
            return jax.lax.with_sharding_constraint(r, spec)
        except (ValueError, RuntimeError, TypeError):
            return r        # off-mesh (single-device tests)

    xs = (to_mb(tokens), to_mb(labels),
          {k: to_mb(v) for k, v in extras.items()})

    def step(carry, mb):
        g_acc, l_acc, a_acc = carry
        mb_tokens, mb_labels, mb_extras = mb
        (_, (loss, aux)), g = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb_tokens, mb_labels, mb_extras)
        g_acc = jax.tree.map(
            lambda a, b: a + b.astype(jnp.float32), g_acc, g)
        return (g_acc, l_acc + loss, a_acc + aux), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (g, l, a), _ = jax.lax.scan(
        step, (g0, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        xs)
    inv = 1.0 / M
    return jax.tree.map(lambda x: x * inv, g), l * inv, a * inv


def make_train_step(cfg: ModelConfig, optimizer: AdamW,
                    rt: RuntimeConfig = RuntimeConfig()):
    """Build the (un-jitted) GSPMD train step; callers jit with shardings."""
    loss_fn = make_loss_fn(cfg, rt)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        grads, loss, aux = _accumulate_grads(loss_fn, state.params, batch, rt)
        params, opt, gnorm = optimizer.update(grads, state.opt, state.params)
        metrics = {"loss": loss, "aux_loss": aux, "grad_norm": gnorm,
                   "lr": optimizer.config.lr_at(opt.step)}
        return TrainState(params, opt, state.compression), metrics

    return train_step


# ---------------------------------------------------------------------------
# Explicit-DP step with int8 + error-feedback gradient sync
# ---------------------------------------------------------------------------

def make_dp_train_step_int8(cfg: ModelConfig, optimizer: AdamW,
                            rt: RuntimeConfig, mesh: Mesh,
                            axis: str = "data"):
    """Pure data-parallel step with the gradient sync under our control.

    Params/opt state replicated; batch sharded over ``axis``.  Each shard
    computes its local gradient, agrees on a per-tensor scale (pmax),
    quantises to int8, psums in int32, and decodes the exact mean of the
    quantised gradients — wire bytes/step drop from 4·P to ~1·P.  The
    per-shard quantisation error is carried in the error-feedback state so
    the accumulated update stays unbiased.
    """
    from repro.compat import shard_map

    loss_fn = make_loss_fn(cfg, rt)
    n = mesh.shape[axis]

    def shard_fn(params, err, tokens, labels):
        grads, loss, aux = _accumulate_grads(
            loss_fn, params, {"tokens": tokens, "labels": labels}, rt)
        st = CompressionState(error=err)
        scales = shared_scale(grads, st, axis=axis)
        q, st = compress_gradients(grads, st, scales)
        q_sum = jax.tree.map(
            lambda x: jax.lax.psum(x.astype(jnp.int32), axis), q)
        mean_g = decompress_sum(q_sum, scales, n)
        return mean_g, st.error, jax.lax.pmean(loss, axis), \
            jax.lax.pmean(aux, axis)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        rep = jax.tree.map(lambda _: P(), state.params)
        data = P(axis)
        grads, err, loss, aux = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(rep, rep, data, data),
            out_specs=(rep, rep, P(), P()),
            check_vma=False)(state.params, state.compression.error,
                             batch["tokens"], batch["labels"])
        params, opt, gnorm = optimizer.update(grads, state.opt, state.params)
        metrics = {"loss": loss, "aux_loss": aux, "grad_norm": gnorm,
                   "lr": optimizer.config.lr_at(opt.step)}
        return TrainState(params, opt, CompressionState(error=err)), metrics

    return train_step
