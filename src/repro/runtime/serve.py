"""Serving runtime: prefill + decode step builders and a batched engine.

``make_prefill_step`` / ``make_decode_step`` produce the pure functions
that the dry-run lowers for the ``prefill_*`` / ``decode_*`` / ``long_*``
shapes.  ``ServeEngine`` is the host-side driver used by the serving
example: continuous batched decode over a slot-based request pool
(join/leave between steps, greedy or temperature sampling).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.faults import ExecutionError
from repro.models.config import ModelConfig
from repro.models.lm import Cache, decode_step, init_cache, prefill


def make_prefill_step(cfg: ModelConfig, capacity: Optional[int] = None):
    """(params, tokens, **extras) -> (last-token logits (B,V), cache)."""

    def prefill_step(params, tokens, **extras):
        return prefill(params, cfg, tokens, capacity=capacity, **extras)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    """(params, cache, token (B,), pos ()) -> (logits (B,V), cache)."""

    def step(params, cache, token, pos):
        return decode_step(params, cfg, cache, token, pos)

    return step


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(logits: jax.Array, key: jax.Array,
           temperature: float = 1.0) -> jax.Array:
    if temperature <= 0:
        return greedy(logits)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature, axis=-1
    ).astype(jnp.int32)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class ServeEngine:
    """Slot-based continuous batching (host-side orchestration).

    A fixed decode batch of ``slots`` sequences advances one token per
    ``step()``; finished sequences free their slot, queued requests are
    prefilled into free slots.  All jitted functions are shape-stable
    (slot count and cache capacity fixed), so serving never recompiles.
    """

    def __init__(self, cfg: ModelConfig, params: Any, *, slots: int,
                 capacity: int, temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.capacity = capacity
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        self._prefill1 = jax.jit(make_prefill_step(cfg, capacity))
        self._decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

        self.cache: Cache = init_cache(cfg, slots, capacity)
        self.cur_token = jnp.zeros((slots,), jnp.int32)
        self.pos = jnp.zeros((), jnp.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._next_rid = 0

    # -- public API -------------------------------------------------------------
    def submit(self, prompt: List[int], max_new: int) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid=rid, prompt=list(prompt),
                                  max_new=max_new))
        return rid

    def step(self) -> int:
        """Admit queued work, decode one token for every active slot.
        Returns the number of active sequences."""
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        self.key, sub = jax.random.split(self.key)
        try:
            logits, self.cache = self._decode(self.params, self.cache,
                                              self.cur_token, self.pos)
        except Exception as e:
            # surface the failure with the affected request identities
            # (same terminal taxonomy as the executor, repro.core.faults)
            rids = [r.rid for r in self.active if r is not None]
            raise ExecutionError(
                f"decode step failed for requests {rids}: "
                f"{type(e).__name__}: {e}") from e
        nxt = sample(logits, sub, self.temperature)
        self.cur_token = nxt
        self.pos = self.pos + 1
        toks = jax.device_get(nxt)
        n_active = 0
        for i, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(toks[i]))
            if req.done:
                self.finished.append(req)
                self.active[i] = None
            else:
                n_active += 1
        return n_active

    def run_to_completion(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
        return self.finished

    # -- internals --------------------------------------------------------------
    def _admit(self) -> None:
        """Prefill queued requests into free slots (batch=1 prefill, then
        splice the slot's cache rows into the shared decode cache)."""
        for i in range(self.slots):
            if self.active[i] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            logits, c1 = self._prefill1(self.params, prompt)
            self.cache = _splice(self.cache, c1, i)
            first = greedy(logits)[0]
            self.cur_token = self.cur_token.at[i].set(first)
            self.pos = jnp.maximum(self.pos, len(req.prompt))
            req.out.append(int(jax.device_get(first)))
            if req.done:
                self.finished.append(req)
            else:
                self.active[i] = req


def _splice(cache: Cache, one: Cache, slot: int) -> Cache:
    """Insert a batch-1 prefill cache into slot ``slot`` of the pool cache.

    Pool and prefill caches share tree structure and rank; the batch dim
    is the (first) dim where the prefill tensor is 1 and the pool tensor
    is ``slots``.  Shorter seq dims (prefill capacity < pool capacity) are
    zero-padded at the tail.
    """
    out = {}
    for k, v in cache.items():
        src = one[k].astype(v.dtype)
        bdim = next(d for d in range(v.ndim)
                    if src.shape[d] == 1 and v.shape[d] != src.shape[d])
        pads = [(0, v.shape[d] - src.shape[d]) if d != bdim else (0, 0)
                for d in range(src.ndim)]
        if any(p != (0, 0) for p in pads):
            src = jnp.pad(src, pads)
        start = [0] * v.ndim
        start[bdim] = slot
        out[k] = jax.lax.dynamic_update_slice(v, src, start)
    return out
