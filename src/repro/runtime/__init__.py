"""Runtime: train/serve step builders, loss, microbatching, remat."""
from repro.runtime.loss import chunked_xent, xent_from_logits
from repro.runtime.serve import (Request, ServeEngine, greedy,
                                 make_decode_step, make_prefill_step, sample)
from repro.runtime.train import (REMAT_POLICIES, RuntimeConfig, TrainState,
                                 init_state, make_dp_train_step_int8,
                                 make_loss_fn, make_train_step)

__all__ = [n for n in dir() if not n.startswith("_")]
