"""Cross-entropy losses: plain, TP-friendly, and seq-chunked.

The seq-chunked variant never materialises the (B, S, V) logits tensor —
it scans the unembedding + log-softmax over sequence chunks, which is the
difference between fitting and OOMing at vocab=256k, seq=4k (the logits
would be 8x the size of all residuals combined).  Under GSPMD with the
vocabulary sharded over the *model* axis the per-chunk logsumexp lowers
to one small all-reduce per chunk.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Defs, mask_padded_vocab, softcap


def xent_from_logits(logits: jax.Array, labels: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    """Mean next-token loss. logits (B,S,V) any float dtype, labels (B,S)
    int32 with -1 = ignore. Returns (sum_loss, n_valid) in f32."""
    lf = logits.astype(jnp.float32)
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, safe[..., None], axis=-1)[..., 0]
    per_tok = (lse - gold) * mask.astype(jnp.float32)
    return per_tok.sum(), mask.sum().astype(jnp.float32)


def chunked_xent(x: jax.Array, params: Defs, cfg: ModelConfig,
                 labels: jax.Array, *, chunks: int = 1
                 ) -> Tuple[jax.Array, jax.Array]:
    """Unembed + cross entropy without materialising full logits.

    x: final hidden states (B, S, d).  ``chunks`` divides S; each chunk
    projects to (B, S/chunks, V), reduces to scalars, and is freed before
    the next chunk (lax.scan sequentialises them).
    """
    B, S, _ = x.shape
    if chunks <= 1 or S % chunks:
        w = params["embed"]["tokens"].T if cfg.tie_embeddings \
            else params["embed"]["unembed"]
        logits = mask_padded_vocab(
            softcap(x @ w.astype(x.dtype), cfg.final_softcap), cfg)
        return xent_from_logits(logits, labels)
    C = S // chunks
    xc = x.reshape(B, chunks, C, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, chunks, C).transpose(1, 0, 2)
    w = params["embed"]["tokens"].T if cfg.tie_embeddings \
        else params["embed"]["unembed"]

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_loss(xi, li):
        # remat: the (B, C, V) logits chunk is recomputed in the backward
        # pass instead of being saved per chunk (V can be 256k).
        logits = mask_padded_vocab(
            softcap(xi @ w.astype(xi.dtype), cfg.final_softcap), cfg)
        return xent_from_logits(logits, li)

    def step(carry, inp):
        tot, cnt = carry
        s, n = chunk_loss(*inp)
        return (tot + s, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc))
    return tot, cnt
