"""Version compatibility shims for the pinned toolchain.

``jax.shard_map`` became a top-level API (with the ``check_vma`` kwarg)
after 0.4.x; older releases expose it as
``jax.experimental.shard_map.shard_map`` with the equivalent kwarg named
``check_rep``.  Import :func:`shard_map` from here everywhere so model
and runtime code can use the modern spelling unconditionally.
"""
from __future__ import annotations

try:
    from jax import shard_map           # jax >= 0.5 style top-level API
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    def shard_map(f=None, *, mesh, in_specs, out_specs, check_vma=True,
                  **kwargs):
        kwargs.setdefault("check_rep", check_vma)
        if f is None:
            return lambda g: _shard_map_exp(g, mesh=mesh, in_specs=in_specs,
                                            out_specs=out_specs, **kwargs)
        return _shard_map_exp(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kwargs)

__all__ = ["shard_map"]
