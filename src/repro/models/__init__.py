"""Architecture substrate: pure-JAX models expressed as Marrow SCTs."""
from repro.models.config import ModelConfig, MoEConfig, SSMConfig
from repro.models.layers import (init_tree, logical_tree, shape_tree,
                                 sharding_tree)
from repro.models.lm import (cache_defs, decode_step, forward_backbone,
                             forward_train, init_cache, model_defs, prefill)
from repro.models.sharding import Rules, constrain, default_rules, spec_for

__all__ = [n for n in dir() if not n.startswith("_")]
