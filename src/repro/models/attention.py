"""GQA attention: blockwise (flash-style) training/prefill + cached decode.

Variants required by the assigned architectures:
  * grouped-query attention (all archs; kv heads <= q heads),
  * sliding-window attention (mixtral, gemma2 local layers),
  * logit soft-capping (gemma2),
  * non-causal self attention (whisper encoder) and cross attention
    (whisper decoder).

The training/prefill path is a **blockwise online-softmax** evaluation
(double ``lax.scan`` over query/key blocks) so the S x S score matrix is
never materialised — mandatory for the 32k prefill shapes.  It is the
pure-jnp oracle of the Pallas ``flash_attention`` kernel
(:mod:`repro.kernels.flash_attention`); on TPU the kernel is swapped in by
``use_pallas=True`` (runtime flag), with identical semantics.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Defs, ParamDef, apply_rope, softcap

NEG_INF = -2.0 ** 30

#: sequence-parallel attention context: when set to a mesh axis name, the
#: q-block axis is computed *in parallel* (vmap instead of scan) and
#: pinned to that axis — the SP path for architectures whose head count
#: does not divide the model axis (minicpm 36H, gemma2 8H, whisper 20H,
#: granite 24H).  See EXPERIMENTS.md §Perf.
import contextlib
import contextvars

_SP_AXIS: contextvars.ContextVar = contextvars.ContextVar(
    "attention_sp", default=None)


@contextlib.contextmanager
def attention_sp(axis: str = "model"):
    tok = _SP_AXIS.set(axis)
    try:
        yield
    finally:
        _SP_AXIS.reset(tok)


def _sp_constrain(x, axis, dim: int):
    """Pin tensor dim ``dim`` to mesh axis ``axis`` (no-op off-mesh)."""
    if axis is None:
        return x
    from jax.sharding import PartitionSpec as P
    spec = [None] * x.ndim
    spec[dim] = axis
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError, TypeError):
        return x


def attn_defs(cfg: ModelConfig, *, cross: bool = False) -> Defs:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    defs: Defs = {
        "wq": ParamDef((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((H, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.use_bias:
        defs["bq"] = ParamDef((H, hd), ("heads", "head_dim"), 0.0)
        defs["bo"] = ParamDef((d,), ("embed",), 0.0)
    return defs


def qkv(x: jax.Array, p: Defs, cfg: ModelConfig,
        positions: Optional[jax.Array] = None,
        kv_x: Optional[jax.Array] = None,
        rope: bool = True) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Project to (B,S,H,hd) / (B,Skv,KV,hd); optionally rope."""
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
    if rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def out_proj(o: jax.Array, p: Defs) -> jax.Array:
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if "bo" in p:
        y = y + p["bo"]
    return y


# ---------------------------------------------------------------------------
# Blockwise online-softmax attention (flash oracle)
# ---------------------------------------------------------------------------

def _mask_block(qi: jax.Array, kj: jax.Array, *, causal: bool,
                window: Optional[int], kv_len: jax.Array | int,
                window_flag: Optional[jax.Array] = None) -> jax.Array:
    """(bq, bk) additive mask for query positions qi x key positions kj.

    ``window_flag``: traced bool scalar enabling the (static-width) window
    — lets a scanned layer stack alternate local/global attention (gemma2)
    without unrolling.
    """
    m = kj[None, :] < kv_len
    if causal:
        m &= kj[None, :] <= qi[:, None]
    if window is not None:
        w = kj[None, :] > qi[:, None] - window
        if window_flag is not None:
            w = w | jnp.logical_not(window_flag)
        m &= w
    return jnp.where(m, 0.0, NEG_INF)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        window_flag: Optional[jax.Array] = None,
                        logit_cap: float = 0.0,
                        scale: Optional[float] = None,
                        q_offset: int = 0,
                        kv_len: Optional[jax.Array] = None,
                        q_block: int = 512, k_block: int = 1024) -> jax.Array:
    """Flash-style attention. q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd) -> (B,Sq,H,hd).

    ``q_offset``: global position of q[0] (prefill continuation / decode).
    ``kv_len``: number of valid key positions (defaults to Sk).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    q_block = min(q_block, Sq)
    k_block = min(k_block, Sk)
    nq, nk = -(-Sq // q_block), -(-Sk // k_block)
    kvl = jnp.asarray(Sk if kv_len is None else kv_len)

    qpad = nq * q_block - Sq
    kpad = nk * k_block - Sk
    qf = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0))) if qpad else q
    kf = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0))) if kpad else k
    vf = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0))) if kpad else v
    # (B, nq, bq, KV, G, hd) blocks
    qb = qf.reshape(B, nq, q_block, KV, G, hd)
    kb = kf.reshape(B, nk, k_block, KV, hd)
    vb = vf.reshape(B, nk, k_block, KV, hd)

    def q_body(qcur, iq):
        qi = q_offset + iq * q_block + jnp.arange(q_block)

        @functools.partial(
            jax.checkpoint,
            policy=jax.checkpoint_policies.nothing_saveable)
        def kv_step(carry, ik):
            # flash-attention backward: recompute the (bq, bk) score tile
            # instead of saving it — without this, the backward pass of a
            # layer holds the full S^2 probability matrix in f32.
            acc, m, l = carry
            kj = ik * k_block + jnp.arange(k_block)
            s = jnp.einsum("bqkgh,bjkh->bkgqj", qcur, kb[:, ik],
                           preferred_element_type=jnp.float32) * sc
            s = softcap(s, logit_cap)
            s = s + _mask_block(qi, kj, causal=causal, window=window,
                                kv_len=kvl, window_flag=window_flag)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bkgqj,bjkh->bkgqh", p,
                            vb[:, ik].astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, KV, G, q_block, hd), jnp.float32)
        m0 = jnp.full((B, KV, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_block), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                      jnp.arange(nk))
        o = acc / jnp.maximum(l[..., None], 1e-30)        # (B,KV,G,bq,hd)
        return o.transpose(0, 3, 1, 2, 4)                 # (B,bq,KV,G,hd)

    sp_axis = _SP_AXIS.get()
    if sp_axis is not None and nq > 1:
        # sequence-parallel path: all q blocks in flight, block axis
        # pinned to the mesh axis — each shard computes its (Sq/n x Sk)
        # slice of the attention map (vmap is spatially parallel; the
        # scan path below is sequential and therefore unshardable)
        qb = _sp_constrain(qb, sp_axis, dim=1)
        ob = jax.vmap(q_body, in_axes=(1, 0), out_axes=0)(
            qb, jnp.arange(nq))                           # (nq,B,bq,KV,G,hd)
        ob = _sp_constrain(ob, sp_axis, dim=0)
    else:
        def q_step(_, iq):
            return None, q_body(qb[:, iq], iq)

        _, ob = jax.lax.scan(q_step, None, jnp.arange(nq))
    o = ob.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_block, H, hd)
    return o[:, :Sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# Cached decode attention (one new token against a KV cache)
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                     pos: jax.Array, window: Optional[int] = None,
                     logit_cap: float = 0.0,
                     scale: Optional[float] = None) -> jax.Array:
    """q: (B,1,H,hd); caches: (B,Scap,KV,hd); ``pos``: current position.

    For rolling (windowed) caches the caller guarantees Scap == window and
    positions are stored modulo the window; masking here is by validity
    count only.
    """
    B, _, H, hd = q.shape
    Scap, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bjkh->bkgj", qg, k_cache.astype(q.dtype),
                   preferred_element_type=jnp.float32) * sc
    s = softcap(s, logit_cap)
    j = jnp.arange(Scap)
    valid = j[None, :] <= pos
    if window is not None and Scap > window:
        valid &= j[None, :] > pos - window
    s = jnp.where(valid[None, None, :, :].reshape(1, 1, 1, Scap), s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgj,bjkh->bkgh", p,
                   v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def update_cache(k_cache: jax.Array, v_cache: jax.Array, k: jax.Array,
                 v: jax.Array, pos: jax.Array,
                 window: Optional[int] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Write one (B,1,KV,hd) k/v at ``pos`` (modulo window for rolling)."""
    Scap = k_cache.shape[1]
    idx = pos % Scap if (window is not None and Scap == window) else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), idx, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), idx, axis=1)
    return k_cache, v_cache
