"""Mamba2 — state-space duality (SSD) layer (arXiv:2405.21060).

Training/prefill uses the **chunked SSD algorithm**: the sequence is split
into chunks of length Q; within-chunk interactions are a masked-decay
matmul (attention-like, MXU-friendly) and cross-chunk interactions pass a
(nh, hd, d_state) state through a ``lax.scan`` recurrence — the Marrow
*Loop* skeleton with device-side state update (paper Sec. 3.1, stage 3).
Decode is the O(1) recurrent update on the carried state.

The within-chunk part is the hot spot mirrored by the Pallas ``ssd_scan``
kernel; this module is its pure-jnp oracle and the default (CPU / dry-run)
path.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Defs, ParamDef, rmsnorm


def ssm_defs(cfg: ModelConfig) -> Defs:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    ds = s.d_state
    return {
        "w_z": ParamDef((d, di), ("embed", "mlp")),
        "w_x": ParamDef((d, di), ("embed", "mlp")),
        "w_B": ParamDef((d, ds), ("embed", "state")),
        "w_C": ParamDef((d, ds), ("embed", "state")),
        "w_dt": ParamDef((d, nh), ("embed", "heads")),
        "dt_bias": ParamDef((nh,), ("heads",), 0.0),
        "A_log": ParamDef((nh,), ("heads",), 0.0),
        "D": ParamDef((nh,), ("heads",), -1.0),
        "conv_x": ParamDef((s.conv_dim, di), ("conv", "mlp"), 0.5),
        "conv_B": ParamDef((s.conv_dim, ds), ("conv", "state"), 0.5),
        "conv_C": ParamDef((s.conv_dim, ds), ("conv", "state"), 0.5),
        "norm": ParamDef((di,), (None,), -1.0),
        "w_out": ParamDef((di, d), ("mlp", "embed")),
    }


def causal_conv(x: jax.Array, w: jax.Array,
                buf: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv along seq. x: (B,S,C), w: (K,C).

    ``buf``: (B,K-1,C) history for decode continuation (prepended).
    """
    K = w.shape[0]
    if buf is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([buf.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(y)


def _project(x: jax.Array, p: Defs, cfg: ModelConfig):
    s = cfg.ssm
    z = x @ p["w_z"]
    xr = x @ p["w_x"]
    Br = x @ p["w_B"]
    Cr = x @ p["w_C"]
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    return z, xr, Br, Cr, dt


def ssd_prefill(x: jax.Array, p: Defs, cfg: ModelConfig, *,
                h0: Optional[jax.Array] = None,
                conv_state: Optional[Dict[str, jax.Array]] = None
                ) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """Full-sequence SSD. x: (B,S,d_model) -> (y, h_final, conv_state).

    Ragged lengths are handled by splitting off the sub-chunk tail and
    chaining the carried state (conv buffers hold *raw* projections, so
    the continuation is exact).
    """
    s = cfg.ssm
    B, S, _ = x.shape
    di, nh, ds, Q = s.d_inner(cfg.d_model), s.n_heads(cfg.d_model), \
        s.d_state, min(s.chunk, x.shape[1])
    if S % Q:
        main = (S // Q) * Q
        y1, h1, conv1 = ssd_prefill(x[:, :main], p, cfg, h0=h0,
                                    conv_state=conv_state)
        y2, h2, conv2 = ssd_prefill(x[:, main:], p, cfg, h0=h1,
                                    conv_state=conv1)
        return jnp.concatenate([y1, y2], axis=1), h2, conv2
    nc = S // Q
    z, xr, Br, Cr, dt = _project(x, p, cfg)
    bx = None if conv_state is None else conv_state["x"]
    bB = None if conv_state is None else conv_state["B"]
    bC = None if conv_state is None else conv_state["C"]
    K1 = s.conv_dim - 1

    def _tail(buf, cur):
        """Last K-1 raw projections incl. history (short-segment safe)."""
        hist = cur if buf is None else jnp.concatenate(
            [buf.astype(cur.dtype), cur], axis=1)
        if hist.shape[1] < K1:
            hist = jnp.pad(hist, ((0, 0), (K1 - hist.shape[1], 0), (0, 0)))
        return hist[:, hist.shape[1] - K1:]

    # conv buffers carry *raw* (pre-conv) projections for continuation
    new_conv = {"x": _tail(bx, xr).astype(jnp.bfloat16),
                "B": _tail(bB, Br).astype(jnp.bfloat16),
                "C": _tail(bC, Cr).astype(jnp.bfloat16)}
    xr = causal_conv(xr, p["conv_x"], bx)
    Br = causal_conv(Br, p["conv_B"], bB)
    Cr = causal_conv(Cr, p["conv_C"], bC)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (nh,) negative
    hd = di // nh
    xh = xr.reshape(B, nc, Q, nh, hd)                     # (B,nc,Q,nh,hd)
    Bc = Br.reshape(B, nc, Q, ds).astype(jnp.float32)
    Cc = Cr.reshape(B, nc, Q, ds).astype(jnp.float32)
    dtc = dt.reshape(B, nc, Q, nh)                        # f32
    h_init = (jnp.zeros((B, nh, ds, hd), jnp.float32)
              if h0 is None else h0.astype(jnp.float32))
    tri = jnp.tril(jnp.ones((Q, Q), bool))

    # ---- chunk loop: within-chunk matmuls + cross-chunk recurrence -------
    # The within-chunk work lives *inside* the scan so the (B,Q,Q,nh)
    # decay tensor exists for one chunk at a time (the chunked-SSD
    # formulation; the Pallas ``ssd_scan`` kernel fuses the same loop).
    def step(h, inp):
        xh_c, B_c, C_c, dt_c = inp                        # one chunk each
        la = dt_c * A                                     # (B,Q,nh) log-decay
        cum = jnp.cumsum(la, axis=1)                      # (B,Q,nh)
        xdt = xh_c.astype(jnp.float32) * dt_c[..., None]  # (B,Q,nh,hd)
        scores = jnp.einsum("bqs,bks->bqk", C_c, B_c)     # (B,Q,Q)
        rel = cum[:, :, None, :] - cum[:, None, :, :]     # (B,Q,Q,nh)
        L = jnp.where(tri[None, :, :, None], jnp.exp(rel), 0.0)
        y = jnp.einsum("bqk,bqkh,bkhe->bqhe", scores, L, xdt)
        # contribution of the carried state (chunk-initial h)
        y = y + jnp.einsum("bqs,bhse,bqh->bqhe", C_c, h, jnp.exp(cum))
        # fold the chunk into the state
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)      # (B,Q,nh)
        S_c = jnp.einsum("bqs,bqh,bqhe->bhse", B_c, decay_to_end, xdt)
        h_new = h * jnp.exp(cum[:, -1])[:, :, None, None] + S_c
        return h_new, y

    h_final, y = jax.lax.scan(
        step, h_init,
        (xh.transpose(1, 0, 2, 3, 4), Bc.transpose(1, 0, 2, 3),
         Cc.transpose(1, 0, 2, 3), dtc.transpose(1, 0, 2, 3)))
    y = y.transpose(1, 0, 2, 3, 4).reshape(B, S, nh, hd)
    y = y + xr.reshape(B, S, nh, -1).astype(jnp.float32) \
        * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, {"scale": p["norm"]}, cfg.norm_eps)
    return y @ p["w_out"], h_final, new_conv


def ssd_decode(x: jax.Array, p: Defs, cfg: ModelConfig, *,
               h: jax.Array, conv_state: Dict[str, jax.Array]
               ) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """One-token recurrent step. x: (B,1,d_model); h: (B,nh,ds,hd)."""
    s = cfg.ssm
    B = x.shape[0]
    di, nh, ds = s.d_inner(cfg.d_model), s.n_heads(cfg.d_model), s.d_state
    z, xr, Br, Cr, dt = _project(x, p, cfg)
    K = s.conv_dim

    def conv1(val, w, buf):
        window = jnp.concatenate([buf.astype(val.dtype), val], axis=1)
        y = jnp.einsum("bkc,kc->bc", window, w)[:, None]
        return jax.nn.silu(y), window[:, 1:]

    xr, nbx = conv1(xr, p["conv_x"], conv_state["x"])
    Br, nbB = conv1(Br, p["conv_B"], conv_state["B"])
    Cr, nbC = conv1(Cr, p["conv_C"], conv_state["C"])
    new_conv = {"x": nbx.astype(conv_state["x"].dtype),
                "B": nbB.astype(conv_state["B"].dtype),
                "C": nbC.astype(conv_state["C"].dtype)}

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xr.reshape(B, nh, -1).astype(jnp.float32)        # (B,nh,hd)
    dt1 = dt.reshape(B, nh)                               # f32
    a = jnp.exp(dt1 * A)                                  # (B,nh)
    Bv = Br.reshape(B, ds).astype(jnp.float32)
    Cv = Cr.reshape(B, ds).astype(jnp.float32)
    hf = h.astype(jnp.float32)
    h_new = hf * a[:, :, None, None] + jnp.einsum(
        "bs,bh,bhe->bhse", Bv, dt1, xh)
    y = jnp.einsum("bs,bhse->bhe", Cv, h_new)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, {"scale": p["norm"]}, cfg.norm_eps)
    return y @ p["w_out"], h_new.astype(h.dtype), new_conv


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    nh = s.n_heads(cfg.d_model)
    return jnp.zeros((batch, nh, s.d_state, s.head_dim), dtype)


def init_conv_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    K = s.conv_dim - 1
    return {"x": jnp.zeros((batch, K, di), dtype),
            "B": jnp.zeros((batch, K, s.d_state), dtype),
            "C": jnp.zeros((batch, K, s.d_state), dtype)}
