"""Mixture-of-Experts FFN: top-k router + sort-based capacity dispatch.

TPU-native dispatch (no per-expert ragged loops): tokens are argsorted by
expert assignment, gathered into an expert-contiguous (E, C, d) buffer,
processed by a *grouped* batched GEMM (the Pallas ``moe_gemm`` kernel on
TPU; jnp einsum oracle here), and scattered back with router weights.
Tokens beyond an expert's capacity C = ceil(cf * k * N / E) are dropped
(standard Switch/GShard semantics).

Sharding: expert weights are (E, d, f) with f over the *model* axis (TP
inside each expert) and optionally d over *data* (FSDP); the token
dispatch stays on the batch axes, so the only cross-device traffic the
layer adds is the f-contraction all-reduce — the SCT edge stays
sharding-stable per the locality rule.
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro.models.config import ModelConfig
from repro.models.layers import Defs, ParamDef, activate, softcap

#: trace-time context selecting the distributed MoE path: (mesh, dp, tp)
_MOE_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "moe_mesh", default=None)


@contextlib.contextmanager
def moe_mesh(mesh: Mesh, dp_axes=("data",), tp_axis: str = "model"):
    """While active, ``moe_ffn`` dispatches tokens *locally* per data
    shard inside ``shard_map`` (per-shard capacity + sort — no global
    argsort collectives), all-gathers the FSDP-sharded expert weights per
    layer (ZeRO-3 style), and psums the f-contraction over the model
    axis.  This is the locality-aware decomposition applied to the MoE
    edge (DESIGN.md §Arch-applicability)."""
    tok = _MOE_MESH.set((mesh, tuple(dp_axes), tp_axis))
    try:
        yield
    finally:
        _MOE_MESH.reset(tok)


def moe_defs(cfg: ModelConfig) -> Defs:
    m = cfg.moe
    d = cfg.d_model
    defs: Defs = {
        "router": ParamDef((d, m.n_experts), ("embed", "experts")),
        "w_in": ParamDef((m.n_experts, d, m.d_ff),
                         ("experts", "embed", "expert_mlp")),
        "w_out": ParamDef((m.n_experts, m.d_ff, d),
                          ("experts", "expert_mlp", "embed")),
    }
    if cfg.gated_mlp:
        defs["w_gate"] = ParamDef((m.n_experts, d, m.d_ff),
                                  ("experts", "embed", "expert_mlp"))
    return defs


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = int(math.ceil(m.capacity_factor * m.top_k * n_tokens / m.n_experts))
    return max(8, -(-c // 8) * 8)      # pad to an 8-multiple (VPU sublane)


def route(x2d: jax.Array, p: Defs, cfg: ModelConfig
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Router: (N,d) -> top-k (weights (N,k), experts (N,k), aux loss)."""
    m = cfg.moe
    logits = x2d.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    logits = softcap(logits, m.router_softcap)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, m.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch): E * sum(f_e * p_e)
    me = probs.mean(0)
    one = jax.nn.one_hot(idx[:, 0], m.n_experts, dtype=jnp.float32)
    ce = one.mean(0)
    aux = m.n_experts * jnp.sum(me * ce)
    return w.astype(x2d.dtype), idx, aux


def moe_ffn(x: jax.Array, p: Defs, cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,d) -> (y, aux_loss).

    Under an active :func:`moe_mesh` context the distributed
    (shard_map) path runs; otherwise the single-shard sort-based
    dispatch below."""
    ctx = _MOE_MESH.get()
    if ctx is not None:
        return _moe_ffn_sharded(x, p, cfg, *ctx)
    return _moe_ffn_local(x, p, cfg)


def _moe_ffn_local(x: jax.Array, p: Defs, cfg: ModelConfig
                   ) -> Tuple[jax.Array, jax.Array]:
    """Sort-based capacity dispatch over the tokens visible locally."""
    m = cfg.moe
    B, S, d = x.shape
    N = B * S
    C = capacity(cfg, N)
    x2 = x.reshape(N, d)
    w, idx, aux = route(x2, p, cfg)                     # (N,k)

    K = m.top_k
    flat_expert = idx.reshape(-1)                       # (N*K,)
    flat_token = jnp.repeat(jnp.arange(N), K)           # token of each slot
    flat_w = w.reshape(-1)

    order = jnp.argsort(flat_expert)                    # expert-contiguous
    tok_sorted = flat_token[order]
    exp_sorted = flat_expert[order]
    w_sorted = flat_w[order]
    # position of each slot within its expert group
    ones = jnp.ones_like(exp_sorted)
    pos_in_expert = jnp.cumsum(ones) - 1
    seg_start = jnp.searchsorted(exp_sorted, jnp.arange(m.n_experts))
    pos_in_expert = pos_in_expert - seg_start[exp_sorted]
    keep = pos_in_expert < C                            # capacity drop
    dest = exp_sorted * C + jnp.where(keep, pos_in_expert, 0)

    # gather tokens into (E*C, d); dropped slots contribute zeros
    xg = jnp.zeros((m.n_experts * C, d), x.dtype)
    src = x2[tok_sorted] * keep[:, None].astype(x.dtype)
    xg = xg.at[dest].add(src)                           # unique dests (<=1 add)
    xe = xg.reshape(m.n_experts, C, d)

    h = jnp.einsum("ecd,edf->ecf", xe, p["w_in"])
    if "w_gate" in p:
        h = activate(h, cfg.activation) * jnp.einsum(
            "ecd,edf->ecf", xe, p["w_gate"])
    else:
        h = activate(h, cfg.activation)
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"])      # (E,C,d)

    # scatter back, weighted
    y_slots = ye.reshape(m.n_experts * C, d)[dest]      # (N*K, d)
    y_slots = y_slots * (w_sorted * keep.astype(w_sorted.dtype))[:, None]
    y2 = jnp.zeros((N, d), x.dtype).at[tok_sorted].add(
        y_slots.astype(x.dtype))
    return y2.reshape(B, S, d), aux


def moe_ffn_dense(x: jax.Array, p: Defs, cfg: ModelConfig
                  ) -> Tuple[jax.Array, jax.Array]:
    """Dense (no-drop) oracle: every expert sees every token, masked combine.

    O(E/k) more FLOPs — used only as the correctness reference in tests.
    """
    m = cfg.moe
    B, S, d = x.shape
    x2 = x.reshape(B * S, d)
    w, idx, aux = route(x2, p, cfg)
    comb = jnp.zeros((B * S, m.n_experts), x.dtype)
    for j in range(m.top_k):
        comb = comb + jax.nn.one_hot(idx[:, j], m.n_experts,
                                     dtype=x.dtype) * w[:, j:j + 1]
    h = jnp.einsum("nd,edf->enf", x2, p["w_in"])
    if "w_gate" in p:
        h = activate(h, cfg.activation) * jnp.einsum(
            "nd,edf->enf", x2, p["w_gate"])
    else:
        h = activate(h, cfg.activation)
    ye = jnp.einsum("enf,efd->end", h, p["w_out"])
    y = jnp.einsum("end,ne->nd", ye, comb)
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Distributed MoE: per-shard dispatch + expert tensor parallelism
# ---------------------------------------------------------------------------

def _moe_ffn_sharded(x: jax.Array, p: Defs, cfg: ModelConfig,
                     mesh: Mesh, dp: Tuple[str, ...], tp: str
                     ) -> Tuple[jax.Array, jax.Array]:
    """shard_map MoE:

      * tokens stay on their data shard — routing, capacity and the
        dispatch sort are **local** (the global argsort of the GSPMD path
        costs an all-to-all of every activation; locality-aware
        decomposition says move the experts' weights instead);
      * expert weights arrive (E, d/dp, f/tp): the d (FSDP) dim is
        all-gathered per layer (backward = reduce-scatter), the f dim
        stays tensor-parallel;
      * the f-contraction partial sums psum over the model axis — the
        single collective the MoE edge fundamentally requires.
    """
    m = cfg.moe
    has_gate = "w_gate" in p
    dp = tuple(a for a in dp if a in mesh.shape)
    tp_in_mesh = tp in mesh.shape
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]

    n_batch = x.shape[0]
    batch_axes = dp if (dp and n_batch % max(n_dp, 1) == 0) else None
    xspec = P(batch_axes, None, None)    # decode B=1: tokens replicated
    d_model = x.shape[-1]
    E, f = m.n_experts, m.d_ff

    def wspec(*dims):
        # replicate any dim whose mesh axes do not divide it
        out = []
        for size, cand in dims:
            if cand is None:
                out.append(None)
                continue
            axes = (cand,) if isinstance(cand, str) else tuple(cand)
            sz = 1
            for a in axes:
                sz *= mesh.shape.get(a, 1)
            ok = all(a in mesh.shape for a in axes) and size % sz == 0
            out.append(cand if ok else None)
        return P(*out)

    in_spec = wspec((E, None), (d_model, dp or None),
                    (f, tp if tp_in_mesh else None))           # w_in/gate
    out_spec_w = wspec((E, None), (f, tp if tp_in_mesh else None),
                       (d_model, dp or None))                  # w_out
    rspec = P()                                                # router

    def body(xl, rw, wi, wg, wo):
        # gather the FSDP (d) dim of the expert weights for this layer
        if dp and in_spec[1] is not None:
            wi = jax.lax.all_gather(wi, dp, axis=1, tiled=True)
            if has_gate:
                wg = jax.lax.all_gather(wg, dp, axis=1, tiled=True)
        if dp and out_spec_w[2] is not None:
            wo = jax.lax.all_gather(wo, dp, axis=2, tiled=True)
        pl = {"router": rw, "w_in": wi, "w_out": wo}
        if has_gate:
            pl["w_gate"] = wg
        y, aux = _moe_ffn_local(xl, pl, cfg)
        if tp_in_mesh:
            y = jax.lax.psum(y, tp)
        if dp and batch_axes is not None:
            aux = jax.lax.pmean(aux, dp)
        return y, aux

    wg_arg = p.get("w_gate", p["w_in"])      # placeholder when ungated
    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(xspec, rspec, in_spec, in_spec, out_spec_w),
        out_specs=(xspec, P()),
        check_vma=False,
    )(x, p["router"], p["w_in"], wg_arg, p["w_out"])
    return y, aux
