"""Substrate layers: parameter definitions, norms, MLPs, rotary embeddings.

Parameters are plain nested dicts of arrays.  Every module publishes a
*parameter definition* tree (``ParamDef`` leaves: shape + logical axis
names + init scale), from which three parallel pytrees derive:

  * real parameters (smoke tests, examples)         — :func:`init_tree`
  * ``ShapeDtypeStruct`` stand-ins (dry-run lowering) — :func:`shape_tree`
  * ``NamedSharding``s via the logical rules          — :func:`sharding_tree`

This is what keeps the SCT edges sharding-stable: every kernel touching a
tensor derives its sharding from the same logical names (paper Sec. 3.1's
global-vision partitioning, GSPMD rendition).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.sharding import Rules, sharding_for


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    scale: float = 1.0          # stddev multiplier (0 => zeros, -1 => ones)

    def stacked(self, n: int) -> "ParamDef":
        return ParamDef((n,) + self.shape, (None,) + self.logical, self.scale)


Defs = Dict[str, Any]            # nested dict of ParamDef


def stack_defs(defs: Defs, n: int) -> Defs:
    return jax.tree.map(lambda d: d.stacked(n), defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def init_tree(rng: jax.Array, defs: Defs, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(rng, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        if d.scale == 0.0:
            out.append(jnp.zeros(d.shape, dtype))
        elif d.scale == -1.0:
            out.append(jnp.ones(d.shape, dtype))
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            std = d.scale / math.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, d.shape, jnp.float32)
                        * std).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def shape_tree(defs: Defs, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs,
        is_leaf=lambda x: isinstance(x, ParamDef))


def sharding_tree(defs: Defs, mesh, rules: Rules):
    return jax.tree.map(
        lambda d: sharding_for(d.shape, d.logical, mesh, rules), defs,
        is_leaf=lambda x: isinstance(x, ParamDef))


def logical_tree(defs: Defs):
    return jax.tree.map(lambda d: d.logical, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_def(d: int) -> Defs:
    return {"scale": ParamDef((d,), (None,), -1.0)}


def rmsnorm(x: jax.Array, p: Defs, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated silu/gelu or squared-ReLU)
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, d_ff: Optional[int] = None,
             mlp_axis: str = "mlp") -> Defs:
    f = d_ff or cfg.d_ff
    d = cfg.d_model
    defs: Defs = {"w_in": ParamDef((d, f), ("embed", mlp_axis)),
                  "w_out": ParamDef((f, d), (mlp_axis, "embed"))}
    if cfg.gated_mlp:
        defs["w_gate"] = ParamDef((d, f), ("embed", mlp_axis))
    return defs


def activate(h: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(h)
    if kind == "gelu":
        return jax.nn.gelu(h)
    if kind == "relu2":                       # nemotron squared-ReLU
        r = jax.nn.relu(h)
        return r * r
    raise ValueError(kind)


def mlp(x: jax.Array, p: Defs, cfg: ModelConfig) -> jax.Array:
    h = x @ p["w_in"]
    if "w_gate" in p:
        h = activate(h, cfg.activation) * (x @ p["w_gate"])
    else:
        h = activate(h, cfg.activation)
    return h @ p["w_out"]


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma2 logit soft-capping; no-op when cap == 0."""
    if cap and cap > 0:
        return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
    return x


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_defs(cfg: ModelConfig) -> Defs:
    V = cfg.padded_vocab
    defs: Defs = {"tokens": ParamDef((V, cfg.d_model), ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((cfg.d_model, V), ("embed", "vocab"))
    return defs


def embed(tokens: jax.Array, p: Defs, cfg: ModelConfig) -> jax.Array:
    e = jnp.take(p["tokens"], tokens, axis=0)
    if cfg.tie_embeddings:
        e = e * jnp.asarray(math.sqrt(cfg.d_model), e.dtype)   # gemma scaling
    return e


def unembed(x: jax.Array, p: Defs, cfg: ModelConfig) -> jax.Array:
    w = p["tokens"].T if cfg.tie_embeddings else p["unembed"]
    logits = softcap(x @ w.astype(x.dtype), cfg.final_softcap)
    return mask_padded_vocab(logits, cfg)


def mask_padded_vocab(logits: jax.Array, cfg: ModelConfig) -> jax.Array:
    """-inf the padded tail ids so loss/sampling never see them."""
    V, Vp = cfg.vocab, cfg.padded_vocab
    if Vp == V:
        return logits
    ids = jnp.arange(Vp)
    neg = jnp.asarray(-2.0 ** 30, logits.dtype)
    return jnp.where(ids < V, logits, neg)
