"""Model configuration covering all assigned architecture families.

One dataclass describes dense / MoE / SSM / hybrid / VLM / audio LM
backbones; per-arch instances live in :mod:`repro.configs`.  The model is
expressed as a Marrow SCT over the substrate —
``Pipeline(Embed, Loop(Block x L), Norm, LMHead)`` — so the paper's
locality-aware decomposition and distribution machinery applies uniformly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                     # per-expert hidden size
    capacity_factor: float = 1.25
    router_softcap: float = 0.0   # 0 = off


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD configuration (arXiv:2405.21060)."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256              # SSD chunk length
    conv_dim: int = 4             # depthwise conv kernel width (stubbed slim)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    activation: str = "silu"      # silu | gelu | relu2
    gated_mlp: Optional[bool] = None   # default: gated for silu/gelu
    use_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    rope_theta: float = 10_000.0
    use_rope: bool = True
    max_pos: int = 0              # learned-position table size (use_rope=False)
    # attention variants
    sliding_window: Optional[int] = None       # SWA width (None = full)
    local_global_pattern: bool = False         # gemma2: alternate local/global
    attn_softcap: float = 0.0                  # gemma2 logit soft-capping
    final_softcap: float = 0.0
    attn_scale: Optional[float] = None
    # family extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0                 # zamba2: attn block period
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500                     # fixed 30 s audio window
    # modality frontend stub (vlm / audio): #positions fed as embeddings
    frontend_positions: int = 0
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # training-recipe hint consumed by the launcher (minicpm: WSD)
    lr_schedule: str = "cosine"
    # embedding tables are padded to this multiple so the vocab dim shards
    # over the model axis (odd tokenizer vocabs: granite/minicpm/internvl2);
    # logits over padded ids are masked to -inf in ``unembed``
    vocab_pad_multiple: int = 128

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))
        if self.gated_mlp is None:
            object.__setattr__(self, "gated_mlp",
                               self.activation in ("silu", "gelu"))
        if self.family in ("moe",) and self.moe is None:
            raise ValueError(f"{self.arch}: moe family needs MoEConfig")
        if self.family in ("ssm", "hybrid") and self.ssm is None:
            raise ValueError(f"{self.arch}: ssm/hybrid family needs SSMConfig")

    # ---- derived quantities ------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = max(self.vocab_pad_multiple, 1)
        return -(-self.vocab // m) * m

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def is_attention_layer(self, layer: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            p = max(self.hybrid_attn_every, 1)
            return (layer + 1) % p == 0
        return True

    def layer_window(self, layer: int) -> Optional[int]:
        """Sliding window of a layer (gemma2 alternates local/global)."""
        if self.local_global_pattern:
            return self.sliding_window if layer % 2 == 0 else None
        return self.sliding_window

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (assignment: SSM/hybrid/windowed only)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None and not self.enc_dec

    # ---- parameter counts (roofline MODEL_FLOPS = 6*N*D) --------------------
    def param_count(self) -> int:
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        return _param_count(self, active_only=True)

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced-config variant (smoke tests)."""
        return dataclasses.replace(self, **kw)


def _ffn_params(cfg: ModelConfig, d_ff: int) -> int:
    if cfg.gated_mlp:                        # gated: w_in, w_gate, w_out
        return 3 * cfg.d_model * d_ff
    return 2 * cfg.d_model * d_ff            # non-gated: w_in, w_out


def _attn_params(cfg: ModelConfig) -> int:
    return (cfg.d_model * cfg.q_dim + 2 * cfg.d_model * cfg.kv_dim
            + cfg.q_dim * cfg.d_model)


def _ssm_params(cfg: ModelConfig) -> int:
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    # in_proj produces [z, x, B, C, dt]; out_proj back to d_model
    in_proj = cfg.d_model * (2 * di + 2 * s.d_state + nh)
    out_proj = di * cfg.d_model
    extra = di * s.conv_dim + 2 * nh + di   # conv, A/dt bias, skip D, norm
    return in_proj + out_proj + extra


def _layer_params(cfg: ModelConfig, layer: int, active_only: bool) -> int:
    n = 2 * cfg.d_model   # two norms
    if cfg.family == "ssm" or (cfg.family == "hybrid"
                               and not cfg.is_attention_layer(layer)):
        return n + _ssm_params(cfg)
    p = n + _attn_params(cfg)
    if cfg.moe is not None:
        per_expert = _ffn_params(cfg, cfg.moe.d_ff)
        router = cfg.d_model * cfg.moe.n_experts
        k = cfg.moe.top_k if active_only else cfg.moe.n_experts
        p += router + k * per_expert
    else:
        p += _ffn_params(cfg, cfg.d_ff)
    return p


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    total = cfg.vocab * cfg.d_model           # embed
    if not cfg.tie_embeddings:
        total += cfg.vocab * cfg.d_model      # unembed
    total += cfg.d_model                      # final norm
    for l in range(cfg.n_layers):
        total += _layer_params(cfg, l, active_only)
    if cfg.enc_dec:
        for l in range(cfg.n_enc_layers):
            total += 2 * cfg.d_model + _attn_params(cfg) \
                + _ffn_params(cfg, cfg.d_ff)
        # decoder cross-attention blocks
        total += cfg.n_layers * (_attn_params(cfg) + cfg.d_model)
    return int(total)
