"""Logical-axis sharding rules — sharding-stable SCT edges (paper Sec. 3.1).

The locality-aware domain decomposition demands that consecutive kernels
sharing a vector observe the *same* partitioning so data persists on
device.  Under GSPMD this becomes: every tensor dimension carries a
**logical axis name**, rules map logical axes to mesh axes, and all kernels
derive their shardings from the same rule set — by construction no edge of
the SCT needs a resharding collective.

Rules are priority lists: the first mesh axis (or axis tuple) that evenly
divides the dimension wins; otherwise the dimension is replicated
(the divisibility fallback is the paper's "relax the constraint, accept
unbalance" escape hatch, Sec. 3.2.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisChoice = Union[str, Tuple[str, ...], None]


@dataclasses.dataclass(frozen=True)
class Rules:
    """logical axis -> ordered candidate mesh axes."""

    table: Dict[str, Tuple[AxisChoice, ...]]

    def lookup(self, logical: Optional[str]) -> Tuple[AxisChoice, ...]:
        if logical is None:
            return (None,)
        return self.table.get(logical, (None,))


def default_rules(mesh: Mesh, *, fsdp: bool = False,
                  seq_shard: bool = False) -> Rules:
    """Production rules for the (pod, data, model) / (data, model) meshes.

    ``fsdp``: additionally shard the non-model dim of big weights over the
    data axes (ZeRO-3-style; XLA inserts per-layer all-gathers under scan).
    ``seq_shard``: shard long sequence dims over the model axis (context /
    sequence parallelism for the 500k shapes).
    """
    dp: Tuple[str, ...] = tuple(a for a in ("pod", "data") if a in
                                mesh.shape)
    mdl = ("model",) if "model" in mesh.shape else ()
    t: Dict[str, Tuple[AxisChoice, ...]] = {
        "batch": (dp,),
        "seq": ((mdl[0],) if seq_shard and mdl else (None,)),
        "embed": ((dp,) if fsdp else (None,)),
        "heads": mdl or (None,),
        "kv_heads": mdl or (None,),
        "head_dim": (None,),
        "mlp": mdl or (None,),
        "vocab": mdl or (None,),
        "experts": mdl or (None,),
        "expert_mlp": mdl or (None,),
        "state": (None,),
        "conv": (None,),
        "cache_batch": (dp,),
        "cache_seq": ((mdl[0],) if seq_shard and mdl else (None,)),
        "frames": (None,),
    }
    return Rules(table=t)


def spec_for(shape: Sequence[int], logical: Sequence[Optional[str]],
             mesh: Mesh, rules: Rules) -> P:
    """PartitionSpec for one tensor: first divisible candidate per dim,
    never reusing a mesh axis across dims."""
    if len(shape) != len(logical):
        raise ValueError(f"rank mismatch {shape} vs {logical}")
    used: set = set()
    out: List[AxisChoice] = []
    for dim, name in zip(shape, logical):
        chosen: AxisChoice = None
        for cand in rules.lookup(name):
            if cand is None:
                continue
            axes = (cand,) if isinstance(cand, str) else tuple(cand)
            if any(a in used or a not in mesh.shape for a in axes):
                continue
            sz = 1
            for a in axes:
                sz *= mesh.shape[a]
            if sz > 0 and dim % sz == 0:
                chosen = axes if len(axes) > 1 else axes[0]
                used.update(axes)
                break
        out.append(chosen)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def sharding_for(shape: Sequence[int], logical: Sequence[Optional[str]],
                 mesh: Mesh, rules: Rules) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, logical, mesh, rules))


def tree_shardings(tree_logical, tree_shapes, mesh: Mesh, rules: Rules):
    """Map a pytree of logical-axis tuples + shapes to NamedShardings."""
    return jax.tree.map(
        lambda lg, sh: sharding_for(sh, lg, mesh, rules),
        tree_logical, tree_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def constrain(x, logical: Sequence[Optional[str]], mesh: Mesh, rules: Rules):
    """with_sharding_constraint via logical names (no-op off-mesh)."""
    try:
        return jax.lax.with_sharding_constraint(
            x, sharding_for(x.shape, logical, mesh, rules))
    except (ValueError, RuntimeError):
        return x
