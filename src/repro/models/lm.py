"""Model assembly: decoder LMs (dense/MoE/SSM/hybrid/VLM) + encoder-decoder.

Every architecture is the same Marrow SCT shape —
``Pipeline(Embed, Loop(Block x L), Norm, LMHead)`` — rendered in JAX as a
``lax.scan`` over stacked per-layer parameters, so the lowered HLO is
depth-independent (one block body) and compiles quickly even for the
104B-parameter configurations.

Three entry points per architecture (built by :mod:`repro.runtime`):

  * ``forward_train``  — full-sequence logits (+ MoE aux loss),
  * ``prefill``        — fills the decode cache, returns last-token logits,
  * ``decode_step``    — one token in, one token out, cache updated.

Heterogeneous layer stacks scan over *groups*:
  gemma2   — pairs (local SWA layer, global layer),
  zamba2   — groups of (hybrid_attn_every-1) Mamba2 layers + 1 attention,
  whisper  — separate encoder and decoder scans (cross-attention blocks).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import ssm as ssm_mod
from repro.models.attention import (attn_defs, blockwise_attention,
                                    decode_attention, out_proj, qkv,
                                    update_cache)
from repro.models.config import ModelConfig
from repro.models.layers import (Defs, ParamDef, embed, embed_defs, mlp,
                                 mlp_defs, rmsnorm, rmsnorm_def, stack_defs,
                                 unembed)
from repro.models.moe import moe_defs, moe_ffn

Cache = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

def attn_block_defs(cfg: ModelConfig, *, cross: bool = False) -> Defs:
    d: Defs = {"ln1": rmsnorm_def(cfg.d_model),
               "attn": attn_defs(cfg),
               "ln2": rmsnorm_def(cfg.d_model)}
    if cfg.moe is not None:
        d["moe"] = moe_defs(cfg)
    else:
        d["ffn"] = mlp_defs(cfg)
    if cross:
        d["ln_x"] = rmsnorm_def(cfg.d_model)
        d["xattn"] = attn_defs(cfg)
    return d


def mamba_block_defs(cfg: ModelConfig) -> Defs:
    return {"ln1": rmsnorm_def(cfg.d_model), "ssm": ssm_mod.ssm_defs(cfg)}


def model_defs(cfg: ModelConfig) -> Defs:
    defs: Defs = {"embed": embed_defs(cfg),
                  "final_norm": rmsnorm_def(cfg.d_model)}
    if not cfg.use_rope:
        defs["pos_embed"] = ParamDef((max(cfg.max_pos, 1), cfg.d_model),
                                     (None, "embed"), 0.02)
    if cfg.enc_dec:
        defs["encoder"] = {
            "layers": stack_defs(attn_block_defs(cfg), cfg.n_enc_layers),
            "final_norm": rmsnorm_def(cfg.d_model)}
        defs["layers"] = stack_defs(attn_block_defs(cfg, cross=True),
                                    cfg.n_layers)
        return defs
    if cfg.family == "ssm":
        defs["layers"] = stack_defs(mamba_block_defs(cfg), cfg.n_layers)
    elif cfg.family == "hybrid":
        g, m = _hybrid_groups(cfg)
        defs["layers"] = {
            "mamba": stack_defs(stack_defs(mamba_block_defs(cfg), m), g),
            "attn": stack_defs(attn_block_defs(cfg), g)}
    elif cfg.local_global_pattern:
        pairs = cfg.n_layers // 2
        defs["layers"] = {"local": stack_defs(attn_block_defs(cfg), pairs),
                          "global": stack_defs(attn_block_defs(cfg), pairs)}
    else:
        defs["layers"] = stack_defs(attn_block_defs(cfg), cfg.n_layers)
    return defs


def _hybrid_groups(cfg: ModelConfig) -> Tuple[int, int]:
    period = max(cfg.hybrid_attn_every, 1)
    if cfg.n_layers % period:
        raise ValueError(f"{cfg.arch}: n_layers {cfg.n_layers} not a "
                         f"multiple of hybrid period {period}")
    return cfg.n_layers // period, period - 1


# ---------------------------------------------------------------------------
# Blocks (train / prefill path)
# ---------------------------------------------------------------------------

def _attn_part(p: Defs, x: jax.Array, cfg: ModelConfig, *,
               positions: jax.Array, causal: bool,
               window: Optional[int] = None,
               window_flag: Optional[jax.Array] = None,
               enc_out: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = qkv(h, p["attn"], cfg, positions=positions,
                  rope=cfg.use_rope)
    o = blockwise_attention(q, k, v, causal=causal, window=window,
                            window_flag=window_flag,
                            logit_cap=cfg.attn_softcap,
                            scale=cfg.attn_scale)
    y = x + out_proj(o, p["attn"])
    if enc_out is not None:                       # cross attention
        hx = rmsnorm(y, p["ln_x"], cfg.norm_eps)
        qx, kx, vx = qkv(hx, p["xattn"], cfg, positions=None, kv_x=enc_out,
                         rope=False)
        ox = blockwise_attention(qx, kx, vx, causal=False,
                                 logit_cap=cfg.attn_softcap,
                                 scale=cfg.attn_scale)
        y = y + out_proj(ox, p["xattn"])
    return y, (k, v)


def _ffn_part(p: Defs, x: jax.Array, cfg: ModelConfig
              ) -> Tuple[jax.Array, jax.Array]:
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, aux = moe_ffn(h, p["moe"], cfg)
    else:
        y, aux = mlp(h, p["ffn"], cfg), jnp.zeros((), jnp.float32)
    return x + y, aux


def attn_block(p: Defs, x: jax.Array, cfg: ModelConfig, *,
               positions: jax.Array, causal: bool = True,
               window: Optional[int] = None,
               window_flag: Optional[jax.Array] = None,
               enc_out: Optional[jax.Array] = None):
    y, kv = _attn_part(p, x, cfg, positions=positions, causal=causal,
                       window=window, window_flag=window_flag,
                       enc_out=enc_out)
    y, aux = _ffn_part(p, y, cfg)
    return y, aux, kv


def mamba_block(p: Defs, x: jax.Array, cfg: ModelConfig, *,
                h0=None, conv0=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    y, h_fin, conv = ssm_mod.ssd_prefill(h, p["ssm"], cfg, h0=h0,
                                         conv_state=conv0)
    return x + y, h_fin, conv


# ---------------------------------------------------------------------------
# Forward (training): tokens -> logits (+aux)
# ---------------------------------------------------------------------------

def _embed_input(params: Defs, cfg: ModelConfig, tokens: jax.Array,
                 extras: Dict[str, jax.Array],
                 pos0: int = 0) -> jax.Array:
    x = embed(tokens, params["embed"], cfg)
    if cfg.frontend_positions and "frontend_embeds" in extras:
        # VLM/audio frontend stub: precomputed patch/frame embeddings
        # replace the first P positions of the sequence.
        fe = extras["frontend_embeds"].astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, fe, (0, 0, 0))
    if not cfg.use_rope:
        S = tokens.shape[1]
        pe = jax.lax.dynamic_slice_in_dim(params["pos_embed"], pos0, S, 0)
        x = x + pe.astype(x.dtype)
    return x


def _run_encoder(params: Defs, cfg: ModelConfig, frames: jax.Array,
                 remat_policy=None, act_spec=None) -> jax.Array:
    """Whisper encoder over stub frame embeddings (B, F, d)."""
    B, F, _ = frames.shape
    pos = _sinusoids(F, cfg.d_model, frames.dtype)
    x = _constrain(frames + pos[None], act_spec)
    positions = jnp.arange(F)[None]

    def body(h, lp):
        y, _, _ = attn_block(lp, h, cfg, positions=positions, causal=False)
        return y, None

    x, _ = jax.lax.scan(
        _maybe_remat(_wrap_body(body, act_spec, carry_tuple=False),
                     remat_policy), x, params["encoder"]["layers"])
    return rmsnorm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def _sinusoids(length: int, channels: int, dtype) -> jax.Array:
    t = jnp.arange(length, dtype=jnp.float32)[:, None]
    half = channels // 2
    inv = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                  / max(half - 1, 1))
    ang = t * inv[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def _maybe_remat(body, policy):
    """Wrap a scan body in jax.checkpoint (activation rematerialisation)."""
    if policy is None:
        return body
    return jax.checkpoint(body, policy=policy)


def _constrain(x, spec):
    """with_sharding_constraint that is a no-op off-mesh (CPU tests)."""
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError, TypeError):
        return x


def _group_layers(tree, g: int):
    """Reshape every stacked-leaf (L, ...) -> (L//g, g, ...)."""
    def one(leaf):
        L = leaf.shape[0]
        if L % g:
            raise ValueError(f"remat_group {g} does not divide layer "
                             f"stack {L}")
        return leaf.reshape((L // g, g) + leaf.shape[1:])
    return jax.tree.map(one, tree)


def _grouped_body(body, g: int, policy=None):
    """Nested checkpointing, scan-of-scan: the outer (checkpointed) body
    advances g layers, so the *persistent* saved stack shrinks g-fold
    (L/g carries instead of L).  The inner per-layer body is checkpointed
    too, so one group's backward recompute holds g transient carries plus
    a single layer's intermediates — never g full layers."""
    if g <= 1:
        return body
    inner = body if policy is None else jax.checkpoint(body, policy=policy)

    def body_g(carry, lp_g):
        out, _ = jax.lax.scan(inner, carry, lp_g)
        return out, None
    return body_g


def _wrap_body(body, act_spec, carry_tuple: bool = True):
    """Pin the scanned carry's activation sharding at every layer —
    without this, GSPMD happily propagates FSDP weight shardings into the
    residual stream (batch replicated, embed sharded: 16x the memory and
    an all-gather per layer)."""
    if act_spec is None:
        return body

    if carry_tuple:
        def wrapped(carry, lp):
            h, aux = carry
            return body((_constrain(h, act_spec), aux), lp)
    else:
        def wrapped(h, lp):
            return body(_constrain(h, act_spec), lp)
    return wrapped


def forward_backbone(params: Defs, cfg: ModelConfig, tokens: jax.Array,
                     remat_policy=None, act_spec=None, remat_group: int = 1,
                     remat_inner_policy=None,
                     **extras) -> Tuple[jax.Array, jax.Array]:
    """tokens (B,S) -> final hidden states (B,S,d), aux-loss scalar.

    ``remat_policy``: jax.checkpoint policy applied to each scanned layer
    body (None = let XLA save what it wants).
    """
    B, S = tokens.shape
    positions = jnp.arange(S)[None]
    x = _constrain(_embed_input(params, cfg, tokens, extras), act_spec)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.enc_dec:
        enc = _run_encoder(params, cfg, extras["frames"], remat_policy,
                           act_spec)

        def body(carry, lp):
            h, aux = carry
            y, a, _ = attn_block(lp, h, cfg, positions=positions,
                                 causal=True, enc_out=enc)
            return (y, aux + a), None

        (x, aux_total), _ = jax.lax.scan(
            _maybe_remat(_grouped_body(_wrap_body(body, act_spec),
                                       remat_group,
                                       remat_inner_policy or remat_policy),
                         remat_policy),
            (x, aux_total), _group_layers(params["layers"], remat_group)
            if remat_group > 1 else params["layers"])
    elif cfg.family == "ssm":
        def body(carry, lp):
            h, aux = carry
            y, _, _ = mamba_block(lp, h, cfg)
            return (y, aux), None

        (x, aux_total), _ = jax.lax.scan(
            _maybe_remat(_grouped_body(_wrap_body(body, act_spec),
                                       remat_group,
                                       remat_inner_policy or remat_policy),
                         remat_policy),
            (x, aux_total), _group_layers(params["layers"], remat_group)
            if remat_group > 1 else params["layers"])
    elif cfg.family == "hybrid":
        def body(carry, lp):
            h, aux = carry

            def mbody(hh, mp):
                y, _, _ = mamba_block(mp, hh, cfg)
                return y, None

            h, _ = jax.lax.scan(mbody, h, lp["mamba"])
            h, a, _ = attn_block(lp["attn"], h, cfg, positions=positions,
                                 causal=True, window=cfg.sliding_window)
            return (h, aux + a), None

        (x, aux_total), _ = jax.lax.scan(
            _maybe_remat(_grouped_body(_wrap_body(body, act_spec),
                                       remat_group,
                                       remat_inner_policy or remat_policy),
                         remat_policy),
            (x, aux_total), _group_layers(params["layers"], remat_group)
            if remat_group > 1 else params["layers"])
    elif cfg.local_global_pattern:
        def body(carry, lp):
            h, aux = carry
            h, a1, _ = attn_block(lp["local"], h, cfg, positions=positions,
                                  window=cfg.sliding_window)
            h, a2, _ = attn_block(lp["global"], h, cfg, positions=positions)
            return (h, aux + a1 + a2), None

        (x, aux_total), _ = jax.lax.scan(
            _maybe_remat(_grouped_body(_wrap_body(body, act_spec),
                                       remat_group,
                                       remat_inner_policy or remat_policy),
                         remat_policy),
            (x, aux_total), _group_layers(params["layers"], remat_group)
            if remat_group > 1 else params["layers"])
    else:
        def body(carry, lp):
            h, aux = carry
            y, a, _ = attn_block(lp, h, cfg, positions=positions,
                                 window=cfg.sliding_window)
            return (y, aux + a), None

        (x, aux_total), _ = jax.lax.scan(
            _maybe_remat(_grouped_body(_wrap_body(body, act_spec),
                                       remat_group,
                                       remat_inner_policy or remat_policy),
                         remat_policy),
            (x, aux_total), _group_layers(params["layers"], remat_group)
            if remat_group > 1 else params["layers"])

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total


def forward_train(params: Defs, cfg: ModelConfig, tokens: jax.Array,
                  remat_policy=None, **extras
                  ) -> Tuple[jax.Array, jax.Array]:
    """tokens (B,S) -> logits (B,S,V), aux-loss scalar."""
    x, aux_total = forward_backbone(params, cfg, tokens,
                                    remat_policy=remat_policy, **extras)
    return unembed(x, params["embed"], cfg), aux_total


# ---------------------------------------------------------------------------
# Decode cache
# ---------------------------------------------------------------------------

def cache_defs(cfg: ModelConfig, batch: int, capacity: int) -> Defs:
    """ParamDef tree of the decode cache (shapes + logical axes)."""
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    kv_log = (None, "cache_batch", "cache_seq", "kv_heads", "head_dim")

    def kv_def(n_layers: int, cap: int) -> ParamDef:
        return ParamDef((n_layers, batch, cap, KV, hd), kv_log, 0.0)

    if cfg.family == "ssm":
        return _ssm_cache_defs(cfg, cfg.n_layers, batch, lead=())
    if cfg.family == "hybrid":
        g, m = _hybrid_groups(cfg)
        d = _ssm_cache_defs(cfg, m, batch, lead=(g,))
        cap = min(capacity, cfg.sliding_window) if cfg.sliding_window \
            else capacity
        d["k"] = kv_def(g, cap)
        d["v"] = kv_def(g, cap)
        return d
    if cfg.local_global_pattern:
        pairs = cfg.n_layers // 2
        w = min(cfg.sliding_window, capacity)
        return {"k_local": kv_def(pairs, w), "v_local": kv_def(pairs, w),
                "k_global": kv_def(pairs, capacity),
                "v_global": kv_def(pairs, capacity)}
    cap = min(capacity, cfg.sliding_window) if cfg.sliding_window \
        else capacity
    d = {"k": kv_def(cfg.n_layers, cap), "v": kv_def(cfg.n_layers, cap)}
    if cfg.enc_dec:
        d["xk"] = kv_def(cfg.n_layers, cfg.enc_frames)
        d["xv"] = kv_def(cfg.n_layers, cfg.enc_frames)
    return d


def _ssm_cache_defs(cfg: ModelConfig, n_layers: int, batch: int,
                    lead: Tuple[int, ...]) -> Defs:
    s = cfg.ssm
    nh, ds, hd = s.n_heads(cfg.d_model), s.d_state, s.head_dim
    di, K1 = s.d_inner(cfg.d_model), s.conv_dim - 1
    nl = (None,) * len(lead)
    return {
        "h": ParamDef(lead + (n_layers, batch, nh, ds, hd),
                      nl + (None, "cache_batch", "heads", None, None), 0.0),
        "conv_x": ParamDef(lead + (n_layers, batch, K1, di),
                           nl + (None, "cache_batch", None, "mlp"), 0.0),
        "conv_B": ParamDef(lead + (n_layers, batch, K1, ds),
                           nl + (None, "cache_batch", None, None), 0.0),
        "conv_C": ParamDef(lead + (n_layers, batch, K1, ds),
                           nl + (None, "cache_batch", None, None), 0.0),
    }


def cache_dtype(key: str, dtype=jnp.bfloat16):
    return jnp.float32 if key == "h" else dtype     # SSM state is f32


def init_cache(cfg: ModelConfig, batch: int, capacity: int,
               dtype=jnp.bfloat16) -> Cache:
    defs = cache_defs(cfg, batch, capacity)
    return {k: jnp.zeros(d.shape, cache_dtype(k, dtype))
            for k, d in defs.items()}


# ---------------------------------------------------------------------------
# Prefill: tokens -> (last logits, filled cache)
# ---------------------------------------------------------------------------

def _fit_window(k: jax.Array, S: int, W: int) -> jax.Array:
    """Pack the last W of S prefilled k/v (B,S,KV,hd) into a rolling cache."""
    if S >= W:
        return jnp.roll(k[:, S - W:], S % W, axis=1)
    return jnp.pad(k, ((0, 0), (0, W - S), (0, 0), (0, 0)))


def prefill(params: Defs, cfg: ModelConfig, tokens: jax.Array,
            capacity: Optional[int] = None, act_spec=None, **extras
            ) -> Tuple[jax.Array, Cache]:
    B, S = tokens.shape
    cap = capacity or S
    positions = jnp.arange(S)[None]
    x = _constrain(_embed_input(params, cfg, tokens, extras), act_spec)

    def pad_cap(k: jax.Array, c: int) -> jax.Array:
        return (k if k.shape[1] == c
                else jnp.pad(k, ((0, 0), (0, c - k.shape[1]),
                                 (0, 0), (0, 0))))

    if cfg.enc_dec:
        enc = _run_encoder(params, cfg, extras["frames"],
                           act_spec=act_spec)

        def body(h, lp):
            y, _, (k, v) = attn_block(lp, h, cfg, positions=positions,
                                      causal=True, enc_out=enc)
            # cross k/v are position-independent: precompute once per layer
            hx = rmsnorm(y, lp["ln_x"], cfg.norm_eps)
            _, xk, xv = qkv(hx, lp["xattn"], cfg, positions=None,
                            kv_x=enc, rope=False)
            return y, {"k": pad_cap(k.astype(jnp.bfloat16), cap),
                       "v": pad_cap(v.astype(jnp.bfloat16), cap),
                       "xk": xk.astype(jnp.bfloat16),
                       "xv": xv.astype(jnp.bfloat16)}

        x, cache = jax.lax.scan(_wrap_body(body, act_spec, carry_tuple=False), x, params["layers"])
    elif cfg.family == "ssm":
        def body(h, lp):
            y, hf, conv = mamba_block(lp, h, cfg)
            return y, {"h": hf.astype(jnp.float32),
                       "conv_x": conv["x"], "conv_B": conv["B"],
                       "conv_C": conv["C"]}

        x, cache = jax.lax.scan(_wrap_body(body, act_spec, carry_tuple=False), x, params["layers"])
    elif cfg.family == "hybrid":
        W = min(cfg.sliding_window, cap) if cfg.sliding_window else cap

        def body(h, lp):
            def mbody(hh, mp):
                y, hf, conv = mamba_block(mp, hh, cfg)
                return y, {"h": hf.astype(jnp.float32), "conv_x": conv["x"],
                           "conv_B": conv["B"], "conv_C": conv["C"]}

            h, mcache = jax.lax.scan(mbody, h, lp["mamba"])
            h, _, (k, v) = attn_block(lp["attn"], h, cfg,
                                      positions=positions,
                                      window=cfg.sliding_window)
            kk = _fit_window(k, S, W) if cfg.sliding_window else pad_cap(k, W)
            vv = _fit_window(v, S, W) if cfg.sliding_window else pad_cap(v, W)
            out = dict(mcache)
            out["k"] = kk.astype(jnp.bfloat16)
            out["v"] = vv.astype(jnp.bfloat16)
            return h, out

        x, cache = jax.lax.scan(_wrap_body(body, act_spec, carry_tuple=False), x, params["layers"])
    elif cfg.local_global_pattern:
        W = min(cfg.sliding_window, cap)

        def body(h, lp):
            h, _, (kl, vl) = attn_block(lp["local"], h, cfg,
                                        positions=positions,
                                        window=cfg.sliding_window)
            h, _, (kg, vg) = attn_block(lp["global"], h, cfg,
                                        positions=positions)
            return h, {"k_local": _fit_window(kl, S, W).astype(jnp.bfloat16),
                       "v_local": _fit_window(vl, S, W).astype(jnp.bfloat16),
                       "k_global": pad_cap(kg.astype(jnp.bfloat16), cap),
                       "v_global": pad_cap(vg.astype(jnp.bfloat16), cap)}

        x, cache = jax.lax.scan(_wrap_body(body, act_spec, carry_tuple=False), x, params["layers"])
    else:
        W = min(cfg.sliding_window, cap) if cfg.sliding_window else cap

        def body(h, lp):
            y, _, (k, v) = attn_block(lp, h, cfg, positions=positions,
                                      window=cfg.sliding_window)
            if cfg.sliding_window:
                k, v = _fit_window(k, S, W), _fit_window(v, S, W)
            else:
                k, v = pad_cap(k, W), pad_cap(v, W)
            return y, {"k": k.astype(jnp.bfloat16),
                       "v": v.astype(jnp.bfloat16)}

        x, cache = jax.lax.scan(_wrap_body(body, act_spec, carry_tuple=False), x, params["layers"])

    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["embed"], cfg)
    return logits[:, 0], cache


# ---------------------------------------------------------------------------
# Decode: one token step
# ---------------------------------------------------------------------------

def _attn_decode(p: Defs, x: jax.Array, cfg: ModelConfig, *,
                 k_cache: jax.Array, v_cache: jax.Array, pos: jax.Array,
                 window: Optional[int],
                 xk: Optional[jax.Array] = None,
                 xv: Optional[jax.Array] = None):
    # barrier: stops XLA hoisting dtype converts of the *whole stacked*
    # cache out of the layer scan (a quantised cache would otherwise
    # materialise a full-precision copy of itself)
    k_cache, v_cache = jax.lax.optimization_barrier((k_cache, v_cache))
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = qkv(h, p["attn"], cfg, positions=pos[None, None],
                  rope=cfg.use_rope)
    k_cache, v_cache = update_cache(k_cache, v_cache, k, v, pos,
                                    window=window)
    o = decode_attention(q, k_cache, v_cache, pos=pos, window=window,
                         logit_cap=cfg.attn_softcap, scale=cfg.attn_scale)
    # barrier: keep the stacked ys cache in its storage dtype — without
    # this, XLA convert-motion accumulates the whole per-layer cache
    # stack in f32 (a CPU-backend bf16-dot legalization artifact)
    k_cache, v_cache = jax.lax.optimization_barrier((k_cache, v_cache))
    y = x + out_proj(o, p["attn"])
    if xk is not None:
        hx = rmsnorm(y, p["ln_x"], cfg.norm_eps)
        qx, _, _ = qkv(hx, p["xattn"], cfg, positions=None, rope=False)
        ox = decode_attention(qx, xk, xv, pos=jnp.asarray(xk.shape[1] - 1),
                              logit_cap=cfg.attn_softcap,
                              scale=cfg.attn_scale)
        y = y + out_proj(ox, p["xattn"])
    y, aux = _ffn_part(p, y, cfg)
    return y, (k_cache, v_cache)


def decode_step(params: Defs, cfg: ModelConfig, cache: Cache,
                token: jax.Array, pos: jax.Array
                ) -> Tuple[jax.Array, Cache]:
    """token (B,), pos scalar -> logits (B,V), updated cache."""
    B = token.shape[0]
    x = _embed_input(params, cfg, token[:, None], {}, pos0=0)
    if not cfg.use_rope:
        # learned positions: replace static slice with the dynamic one
        x = embed(token[:, None], params["embed"], cfg) + \
            jax.lax.dynamic_slice_in_dim(params["pos_embed"], pos, 1, 0
                                         )[None].astype(jnp.bfloat16)

    if cfg.enc_dec:
        def body(h, lc):
            lp, c = lc
            y, (k, v) = _attn_decode(lp, h, cfg, k_cache=c["k"],
                                     v_cache=c["v"], pos=pos, window=None,
                                     xk=c["xk"], xv=c["xv"])
            return y, {"k": k, "v": v, "xk": c["xk"], "xv": c["xv"]}

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    elif cfg.family == "ssm":
        def body(h, lc):
            lp, c = lc
            hh = rmsnorm(h, lp["ln1"], cfg.norm_eps)
            y, hs, conv = ssm_mod.ssd_decode(
                hh, lp["ssm"], cfg, h=c["h"],
                conv_state={"x": c["conv_x"], "B": c["conv_B"],
                            "C": c["conv_C"]})
            return h + y, {"h": hs, "conv_x": conv["x"],
                           "conv_B": conv["B"], "conv_C": conv["C"]}

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    elif cfg.family == "hybrid":
        W = cache["k"].shape[2]

        def body(h, lc):
            lp, c = lc

            def mbody(hh, mc):
                mp, cc = mc
                hn = rmsnorm(hh, mp["ln1"], cfg.norm_eps)
                y, hs, conv = ssm_mod.ssd_decode(
                    hn, mp["ssm"], cfg, h=cc["h"],
                    conv_state={"x": cc["conv_x"], "B": cc["conv_B"],
                                "C": cc["conv_C"]})
                return hh + y, {"h": hs, "conv_x": conv["x"],
                                "conv_B": conv["B"], "conv_C": conv["C"]}

            mc_in = {k2: c[k2] for k2 in
                     ("h", "conv_x", "conv_B", "conv_C")}
            h, mcache = jax.lax.scan(mbody, h, (lp["mamba"], mc_in))
            h, (k, v) = _attn_decode(
                lp["attn"], h, cfg, k_cache=c["k"], v_cache=c["v"], pos=pos,
                window=cfg.sliding_window if W == cfg.sliding_window
                else None)
            out = dict(mcache)
            out["k"], out["v"] = k, v
            return h, out

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    elif cfg.local_global_pattern:
        W = cache["k_local"].shape[2]

        def body(h, lc):
            lp, c = lc
            h, (kl, vl) = _attn_decode(
                lp["local"], h, cfg, k_cache=c["k_local"],
                v_cache=c["v_local"], pos=pos,
                window=cfg.sliding_window if W == cfg.sliding_window
                else None)
            h, (kg, vg) = _attn_decode(lp["global"], h, cfg,
                                       k_cache=c["k_global"],
                                       v_cache=c["v_global"], pos=pos,
                                       window=None)
            return h, {"k_local": kl, "v_local": vl,
                       "k_global": kg, "v_global": vg}

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    else:
        W = cache["k"].shape[2]
        win = (cfg.sliding_window
               if cfg.sliding_window and W == cfg.sliding_window else None)

        def body(h, lc):
            lp, c = lc
            y, (k, v) = _attn_decode(lp, h, cfg, k_cache=c["k"],
                                     v_cache=c["v"], pos=pos, window=win)
            return y, {"k": k, "v": v}

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["embed"], cfg)
    return logits[:, 0], new_cache
