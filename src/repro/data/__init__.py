"""Deterministic, shard-resumable synthetic data pipeline."""
from repro.data.pipeline import (DataConfig, SyntheticLM, batch_at,
                                 host_shard_batch)

__all__ = [n for n in dir() if not n.startswith("_")]
