"""Synthetic LM token pipeline — deterministic, stateless, shard-resumable.

Design for thousand-node runs: the batch for step ``s`` is a *pure
function* of ``(seed, s)`` — ``batch_at`` folds the step into the PRNG key
— so there is no iterator state to checkpoint or rebalance.  Restart,
elastic rescale, and straggler re-execution all reduce to "recompute
``batch_at(step)``"; two hosts can never disagree about a batch, and a
host only materialises its own slice (:func:`host_shard_batch`).

Token distribution: Zipfian over the vocabulary (natural-language-like
mass concentration) with a per-sequence "document id" mixed into the key,
plus next-token-structured targets (labels = tokens shifted by one), so
the cross-entropy actually decreases during the example trainings.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1     # 0 = uniform
    # markov structure: next token correlates with the previous one, giving
    # the model signal to learn (examples show loss decreasing)
    markov_strength: float = 0.7


def _zipf_cdf(vocab: int, alpha: float) -> np.ndarray:
    if alpha <= 0:
        return np.linspace(1.0 / vocab, 1.0, vocab)
    w = 1.0 / np.arange(1, vocab + 1, dtype=np.float64) ** alpha
    return np.cumsum(w / w.sum())


# CDF cache per (vocab, alpha) — hosts share it read-only.
_CDF_CACHE: Dict[Tuple[int, float], jax.Array] = {}


def _cdf(vocab: int, alpha: float) -> jax.Array:
    key = (vocab, alpha)
    if key not in _CDF_CACHE:
        _CDF_CACHE[key] = jnp.asarray(_zipf_cdf(vocab, alpha), jnp.float32)
    return _CDF_CACHE[key]


def batch_at(cfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    """Global batch for one step: {'tokens': (B,S) i32, 'labels': (B,S) i32}.

    labels[i, t] = tokens[i, t+1]; the final position is masked with -1
    (ignored by the loss).
    """
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab
    cdf = _cdf(V, cfg.zipf_alpha)
    u = jax.random.uniform(key, (B, S + 1))
    base = jnp.searchsorted(cdf, u).astype(jnp.int32)
    if cfg.markov_strength > 0:
        kkey = jax.random.fold_in(key, 1)
        keep = jax.random.uniform(kkey, (B, S + 1)) < cfg.markov_strength
        # structured successor: x -> (x * 31 + doc) % V, deterministic per doc
        doc = jax.random.randint(jax.random.fold_in(key, 2), (B, 1), 0, 97)
        prev = jnp.roll(base, 1, axis=1)
        succ = (prev * 31 + doc).astype(jnp.int32) % V
        toks = jnp.where(keep, succ, base)
    else:
        toks = base
    tokens = toks[:, :S]
    labels = jnp.where(jnp.arange(S)[None] == S - 1, -1, toks[:, 1:S + 1])
    return {"tokens": tokens, "labels": labels.astype(jnp.int32)}


def host_shard_batch(cfg: DataConfig, step: int, *, host_index: int,
                     host_count: int) -> Dict[str, jax.Array]:
    """This host's slice of the step's global batch (batch-dim contiguous).

    Materialises only ``B/host_count`` sequences — each host computes the
    full key schedule but only its rows, keeping per-host memory flat as
    the job scales out.
    """
    if cfg.global_batch % host_count:
        raise ValueError(f"global_batch {cfg.global_batch} not divisible by "
                         f"host_count {host_count}")
    per = cfg.global_batch // host_count
    full = batch_at(cfg, step)          # lazy under jit; sliced before device
    lo = host_index * per
    return {k: v[lo:lo + per] for k, v in full.items()}


class SyntheticLM:
    """Iterator facade with checkpointable cursor (just the step index)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        b = batch_at(self.cfg, self.step)
        self.step += 1
        return b

    # -- checkpoint interface -------------------------------------------------
    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step}

    def load_state_dict(self, d: Dict[str, int]) -> None:
        self.step = int(d["step"])
