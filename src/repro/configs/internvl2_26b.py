"""internvl2-26b — InternViT frontend (stub) + InternLM2-20B backbone:
48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553
[arXiv:2404.16821; hf].  The vision tower is a STUB per the assignment:
``input_specs`` provides precomputed patch embeddings that replace the
first ``frontend_positions`` sequence positions."""
from repro.models.config import ModelConfig

ARCH = "internvl2-26b"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="vlm",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab=92553, head_dim=128,
        frontend_positions=256,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=515, head_dim=16,
        frontend_positions=8,
    )
