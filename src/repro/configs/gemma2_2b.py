"""gemma2-2b — 26L d_model=2304 8H (GQA kv=4) head_dim=256 d_ff=9216
vocab=256000; alternating local(4096)/global layers, logit softcaps,
tied embeddings [arXiv:2408.00118; hf]."""
from repro.models.config import ModelConfig

ARCH = "gemma2-2b"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="dense",
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
        d_ff=9216, vocab=256000, head_dim=256,
        activation="gelu",
        sliding_window=4096, local_global_pattern=True,
        attn_softcap=50.0, final_softcap=30.0,
        attn_scale=256 ** -0.5,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512, head_dim=32,
        activation="gelu",
        sliding_window=16, local_global_pattern=True,
        attn_softcap=50.0, final_softcap=30.0,
        attn_scale=32 ** -0.5,
        tie_embeddings=True,
    )
