"""minicpm-2b — 40L d_model=2304 36H (GQA kv=36) d_ff=5760 vocab=122753,
llama-like dense arch trained with the WSD schedule [arXiv:2404.06395;
hf].  The WSD recipe is carried as ``lr_schedule`` and consumed by the
launcher (``repro.optim.schedules.wsd_schedule``)."""
from repro.models.config import ModelConfig

ARCH = "minicpm-2b"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="dense",
        n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
        d_ff=5760, vocab=122753, head_dim=64,
        tie_embeddings=True,
        lr_schedule="wsd",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=513, head_dim=16,
        tie_embeddings=True,
        lr_schedule="wsd",
    )
