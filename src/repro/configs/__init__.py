"""Architecture registry: ``--arch <id>`` resolution for every launcher.

``get_config(name)`` returns the exact published configuration;
``get_smoke(name)`` a reduced same-family variant for CPU smoke tests.
"""
from __future__ import annotations

from typing import Callable, Dict, List

from repro.configs import (command_r_plus, gemma2_2b, granite_moe_3b,
                           internvl2_26b, mamba2_1_3b, minicpm_2b,
                           mixtral_8x22b, nemotron_4_15b, whisper_large_v3,
                           zamba2_2_7b)
from repro.configs.shapes import SHAPES, ShapeSpec, applicable, input_specs
from repro.models.config import ModelConfig

_MODULES = (mixtral_8x22b, granite_moe_3b, internvl2_26b, gemma2_2b,
            minicpm_2b, command_r_plus, nemotron_4_15b, whisper_large_v3,
            mamba2_1_3b, zamba2_2_7b)

ARCHS: Dict[str, object] = {m.ARCH: m for m in _MODULES}


def arch_names() -> List[str]:
    return list(ARCHS.keys())


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; known: {arch_names()}")
    return ARCHS[name].config()


def get_smoke(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; known: {arch_names()}")
    return ARCHS[name].smoke()


def cells(include_skipped: bool = False):
    """All (arch, shape) cells; skipped cells carry their reason."""
    out = []
    for name in arch_names():
        cfg = get_config(name)
        for shape in SHAPES.values():
            ok, reason = applicable(cfg, shape)
            if ok or include_skipped:
                out.append((name, shape.name, ok, reason))
    return out


__all__ = ["ARCHS", "SHAPES", "ShapeSpec", "applicable", "arch_names",
           "cells", "get_config", "get_smoke", "input_specs"]
