"""mixtral-8x22b — 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8 experts top-2, sliding-window attention [arXiv:2401.04088; hf]."""
from repro.models.config import ModelConfig, MoEConfig

ARCH = "mixtral-8x22b"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=0, vocab=32768, head_dim=128,
        sliding_window=4096,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=16384),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=0, vocab=512, head_dim=16,
        sliding_window=16,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=96),
    )
