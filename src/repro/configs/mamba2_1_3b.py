"""mamba2-1.3b — 48L d_model=2048, attention-free SSD (state-space
duality), ssm_state=128, vocab=50280 [arXiv:2405.21060; unverified]."""
from repro.models.config import ModelConfig, SSMConfig

ARCH = "mamba2-1.3b"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="ssm",
        n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=50280, head_dim=64,
        tie_embeddings=True,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=512, head_dim=16,
        tie_embeddings=True,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=16),
    )
