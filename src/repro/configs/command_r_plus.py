"""command-r-plus-104b — 64L d_model=12288 96H (GQA kv=8) d_ff=33792
vocab=256000, no-bias GQA [hf:CohereForAI/c4ai-command-r-v01;
unverified]."""
from repro.models.config import ModelConfig

ARCH = "command-r-plus-104b"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="dense",
        n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
        d_ff=33792, vocab=256000, head_dim=128,
        use_bias=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke", family="dense",
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
        d_ff=256, vocab=512, head_dim=16,
    )
