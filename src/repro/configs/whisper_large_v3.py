"""whisper-large-v3 — enc-dec, 32+32L d_model=1280 20H (kv=20) d_ff=5120
vocab=51866 [arXiv:2212.04356; unverified].  The conv/log-mel audio
frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, 1500, d) for the encoder; the decoder
uses learned positions (no rope) and non-gated GELU MLPs."""
from repro.models.config import ModelConfig

ARCH = "whisper-large-v3"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="audio",
        n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
        d_ff=5120, vocab=51866, head_dim=64,
        activation="gelu", gated_mlp=False, use_bias=True,
        enc_dec=True, n_enc_layers=32, enc_frames=1500,
        use_rope=False, max_pos=32768,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, head_dim=16,
        activation="gelu", gated_mlp=False, use_bias=True,
        enc_dec=True, n_enc_layers=2, enc_frames=8,
        use_rope=False, max_pos=128,
    )
