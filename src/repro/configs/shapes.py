"""Assigned input shapes and per-cell applicability + input specs.

The four LM shapes (seq_len x global_batch):

  train_4k     4,096 x 256   lowers ``train_step``
  prefill_32k  32,768 x 32   lowers ``prefill`` (inference-prefill)
  decode_32k   32,768 x 128  lowers ``serve_step`` (KV cache of seq_len)
  long_500k    524,288 x 1   lowers ``serve_step``; sub-quadratic archs only

``input_specs`` returns weak-type-correct ``jax.ShapeDtypeStruct``
stand-ins for every model input of a cell — no device allocation, exactly
what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs?, reason).  Skips follow the assignment rules:
    long_500k only for sub-quadratic archs (SSM / hybrid / windowed)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (f"{cfg.arch} is pure full-attention; 500k decode "
                       "needs sub-quadratic attention (assignment skip)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for the data inputs of one (arch x shape) cell.

    train:   tokens + labels (+ modality stubs)
    prefill: tokens (+ modality stubs)
    decode:  token + pos (the cache/params structs come from the model)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        out["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    elif shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    else:  # decode
        out["token"] = jax.ShapeDtypeStruct((B,), i32)
        out["pos"] = jax.ShapeDtypeStruct((), i32)
    # modality frontend stubs (assignment: precomputed frame/patch embeds)
    if shape.kind in ("train", "prefill"):
        if cfg.enc_dec:
            out["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_frames, cfg.d_model), dtype)
        elif cfg.frontend_positions:
            out["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_positions, cfg.d_model), dtype)
    return out
