"""granite-moe-3b-a800m — 32L d_model=1536 24H (GQA kv=8) d_ff=512/expert
vocab=49155, MoE 40 experts top-8 [hf:ibm-granite/granite-3.0-*; hf]."""
from repro.models.config import ModelConfig, MoEConfig

ARCH = "granite-moe-3b-a800m"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=0, vocab=49155, head_dim=64,
        tie_embeddings=True,
        moe=MoEConfig(n_experts=40, top_k=8, d_ff=512),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=0, vocab=515, head_dim=16,       # odd vocab kept odd on purpose
        tie_embeddings=True,
        moe=MoEConfig(n_experts=8, top_k=4, d_ff=32),
    )
