"""zamba2-2.7b — 54L hybrid: Mamba2 blocks with a (shared-pattern)
attention block every 6 layers; d_model=2560 32H (kv=32) d_ff=10240
vocab=32000 ssm_state=64 [arXiv:2411.15242; hf].  We instantiate the
attention blocks unshared (per-group weights); see DESIGN.md
§Arch-applicability."""
from repro.models.config import ModelConfig, SSMConfig

ARCH = "zamba2-2.7b"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab=32000, head_dim=80,
        ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk=256),
        hybrid_attn_every=6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, head_dim=16,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=16),
        hybrid_attn_every=2,
    )
