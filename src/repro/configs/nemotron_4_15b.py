"""nemotron-4-15b — 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000, squared-ReLU non-gated MLP [arXiv:2402.16819; unverified]."""
from repro.models.config import ModelConfig

ARCH = "nemotron-4-15b"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="dense",
        n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=24576, vocab=256000, head_dim=128,
        activation="relu2",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=192, vocab=512, head_dim=16,
        activation="relu2",
    )
