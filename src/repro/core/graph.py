"""JobGraph IR + concurrent graph execution (paper Fig. 2 work queues).

The paper's runtime decouples *describing* a compound computation from
*dispatching* it: the task launcher feeds per-device work queues, and a
compound computation is a general multi-kernel composition — not merely
a linear chain.  This module is that decoupling for the reproduction:

  * :class:`JobGraph` — the intermediate representation.  Nodes bind an
    SCT to named inputs; edges carry data dependencies and residency
    intent.  Construction is append-only (a node may only depend on
    nodes added before it), so a ``JobGraph`` is acyclic by
    construction and insertion order is always a valid topological
    order.  A linear chain (:meth:`JobGraph.from_chain`) is the
    degenerate case.
  * :class:`GraphHandle` — the asynchronous completion handle returned
    by ``Scheduler.submit`` / ``Session.submit``: per-node state,
    per-node :class:`~repro.core.scheduler.ScheduledRun` results,
    per-node execution spans, and a blocking :meth:`GraphHandle.result`.
  * :class:`GraphDriver` — the execution engine.  On the threaded
    executor, nodes whose dependencies are satisfied are submitted to
    the scheduler's node pool as soon as they become ready, so
    *independent* nodes genuinely overlap (their segments land in
    disjoint per-device work queues).  On a virtual-clock executor
    (:class:`~repro.core.simulator.SimulatedExecutor`) the driver runs
    nodes deterministically in topological order on the simulated
    timeline, modelling per-device work-queue contention, so fan-out /
    fan-in overlap is testable bit-for-bit without hardware.

Residency intent travels along graph edges: a node whose single
successor is its sole consumer (a *chain edge*) keeps its outputs
slot-resident (:class:`~repro.core.executor.ResidentPartition`) and the
successor consumes them slot-locally — the ``run_chain`` optimisation
generalised to DAGs.  Fan-out and fan-in edges merge (the safe path),
so graph execution is never less correct than sequential execution.

Failure semantics: a node whose retries are exhausted is *contained* —
its descendants are marked ``skipped``, independent branches run to
completion, and :meth:`GraphHandle.result` raises a single
:class:`~repro.core.faults.ExecutionError` identifying the first failed
node in topological order (with the per-slot fault records attached).
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import threading
import time
from typing import (Any, Callable, Dict, Iterable, Iterator, List, Optional,
                    Sequence, Tuple)

from repro.core.faults import ExecutionError
from repro.core.skeletons import SCT


class GraphError(ValueError):
    """Malformed JobGraph: unknown dependency, duplicate node, empty graph."""


@dataclasses.dataclass
class JobNode:
    """One unit of graph work: an SCT bound to its dependency edges.

    ``residency`` is the node's residency intent for its outgoing edge:
    ``None`` (auto — keep resident on chain edges), ``False`` (always
    merge), ``True`` (request residency; still only honoured on a chain
    edge over a residency-capable executor, since fan-out consumers need
    the merged arrays).
    """

    name: str
    sct: SCT
    deps: Tuple[str, ...] = ()
    residency: Optional[bool] = None


class JobGraph:
    """Append-only DAG of SCT executions.

    ``add`` may only reference already-added nodes in ``after``, which
    makes cycles unrepresentable and keeps insertion order a valid
    topological order — the scheduling layers rely on both properties.
    """

    def __init__(self) -> None:
        self._nodes: Dict[str, JobNode] = {}
        self._succ: Dict[str, List[str]] = {}

    # -- construction --------------------------------------------------------
    def add(self, sct: SCT, *, name: Optional[str] = None,
            after: Iterable[str] = (),
            residency: Optional[bool] = None) -> str:
        """Add one node; returns its name (auto-derived from the SCT)."""
        if isinstance(after, str):
            after = (after,)
        deps = tuple(dict.fromkeys(after))
        for d in deps:
            if d not in self._nodes:
                raise GraphError(
                    f"unknown dependency {d!r}: nodes may only depend on "
                    "previously added nodes")
        if name is None:
            base = getattr(sct, "name", None) or "node"
            name = base
            i = len(self._nodes)
            while name in self._nodes:
                name = f"{base}.{i}"
                i += 1
        elif name in self._nodes:
            raise GraphError(f"duplicate node name {name!r}")
        self._nodes[name] = JobNode(name=name, sct=sct, deps=deps,
                                    residency=residency)
        self._succ[name] = []
        for d in deps:
            self._succ[d].append(name)
        return name

    def add_chain(self, scts: Sequence[SCT], *,
                  after: Iterable[str] = ()) -> List[str]:
        """Add a linear chain of nodes; returns their names in order."""
        names: List[str] = []
        prev: Iterable[str] = after
        for sct in scts:
            n = self.add(sct, after=prev)
            names.append(n)
            prev = (n,)
        return names

    @classmethod
    def from_chain(cls, scts: Sequence[SCT]) -> "JobGraph":
        """A linear chain — the degenerate JobGraph ``run_chain`` maps to."""
        g = cls()
        g.add_chain(list(scts))
        return g

    def validate(self) -> None:
        if not self._nodes:
            raise GraphError("empty graph: nothing to execute")

    # -- structure -----------------------------------------------------------
    def __len__(self) -> int:
        return len(self._nodes)

    def __contains__(self, name: str) -> bool:
        return name in self._nodes

    def __iter__(self) -> Iterator[str]:
        return iter(self._nodes)

    def names(self) -> List[str]:
        return list(self._nodes)

    @property
    def nodes(self) -> List[JobNode]:
        return list(self._nodes.values())

    def node(self, name: str) -> JobNode:
        try:
            return self._nodes[name]
        except KeyError:
            raise GraphError(f"unknown node {name!r}") from None

    def deps(self, name: str) -> Tuple[str, ...]:
        return self.node(name).deps

    def successors(self, name: str) -> List[str]:
        self.node(name)
        return list(self._succ[name])

    def in_degree(self, name: str) -> int:
        return len(self.deps(name))

    def out_degree(self, name: str) -> int:
        return len(self.successors(name))

    def roots(self) -> List[str]:
        return [n for n in self._nodes if not self._nodes[n].deps]

    def sinks(self) -> List[str]:
        return [n for n in self._nodes if not self._succ[n]]

    def topo_order(self) -> List[str]:
        # append-only construction: insertion order is topological
        return list(self._nodes)

    def signature(self) -> Tuple:
        """Structural identity of the graph, for whole-graph plan caching.

        Two graphs share a signature when they bind the same SCTs (by
        ``unique_id``) over the same dependency structure with the same
        residency intents — node *names* are labels and do not
        participate.  Together with the shapes of the submit-time input
        arrays this keys the scheduler's
        :class:`~repro.core.scheduler.GraphPlanCache`.
        """
        pos = {n: i for i, n in enumerate(self._nodes)}
        return tuple((node.sct.unique_id(),
                      tuple(pos[d] for d in node.deps),
                      node.residency)
                     for node in self._nodes.values())

    def ancestors(self, name: str) -> List[str]:
        """Transitive dependencies of ``name``, in topological order."""
        seen = set()
        stack = list(self.deps(name))
        while stack:
            d = stack.pop()
            if d not in seen:
                seen.add(d)
                stack.extend(self.deps(d))
        return [n for n in self._nodes if n in seen]

    def is_chain_edge(self, u: str, v: str) -> bool:
        """True when v is u's only successor and u is v's only dependency."""
        return self.successors(u) == [v] and self.deps(v) == (u,)


# ---------------------------------------------------------------------------
# Completion handle
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GraphResult:
    """Settled outcome of one graph execution.

    ``outputs`` merges the sink nodes' outputs (topological order, later
    sinks win on name clashes); ``runs`` maps node name to its
    :class:`~repro.core.scheduler.ScheduledRun`; ``spans`` maps node
    name to its ``(start_us, end_us)`` execution window — wall-clock
    microseconds relative to submission on the threaded executor,
    virtual simulated-time microseconds on the simulator.
    """

    outputs: Dict[str, Any]
    runs: Dict[str, Any]
    spans: Dict[str, Tuple[float, float]]
    order: List[str]


class GraphHandle:
    """Asynchronous handle for one submitted JobGraph.

    Node states progress ``pending -> queued -> running -> done``;
    terminal failures mark the node ``failed`` and every descendant
    ``skipped``.  ``result`` blocks for completion and raises the
    aggregate :class:`~repro.core.faults.ExecutionError` when any node
    failed (independent branches still ran to completion and their runs
    stay accessible via :attr:`runs`).
    """

    def __init__(self, graph: JobGraph, request_id: str):
        self.graph = graph
        self.request_id = request_id
        self.runs: Dict[str, Any] = {}
        self.error: Optional[ExecutionError] = None
        self._state: Dict[str, str] = {n: "pending" for n in graph.names()}
        self._spans: Dict[str, Tuple[float, float]] = {}
        self._lock = threading.Lock()
        self._done = threading.Event()
        self._callbacks: List[Callable[["GraphHandle"], None]] = []

    # -- completion ----------------------------------------------------------
    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._done.wait(timeout)

    def result(self, timeout: Optional[float] = None) -> GraphResult:
        if not self._done.wait(timeout):
            raise cf.TimeoutError(
                f"graph {self.request_id!r} did not complete "
                f"within {timeout}s")
        if self.error is not None:
            raise self.error
        return GraphResult(outputs=self.outputs(), runs=dict(self.runs),
                           spans=self.spans(),
                           order=self.graph.topo_order())

    def add_done_callback(self,
                          fn: Callable[["GraphHandle"], None]) -> None:
        with self._lock:
            if not self._done.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    # -- introspection -------------------------------------------------------
    def status(self) -> Dict[str, str]:
        with self._lock:
            return dict(self._state)

    def spans(self) -> Dict[str, Tuple[float, float]]:
        with self._lock:
            return dict(self._spans)

    def outputs(self) -> Dict[str, Any]:
        """Merged outputs of the graph's sink nodes (topological order)."""
        out: Dict[str, Any] = {}
        for name in self.graph.topo_order():
            if not self.graph.successors(name):
                r = self.runs.get(name)
                if r is not None and r.outputs:
                    out.update(r.outputs)
        return out

    # -- driver-side mutators ------------------------------------------------
    def _mark(self, name: str, state: str) -> None:
        with self._lock:
            self._state[name] = state

    def _finish(self, error: Optional[ExecutionError]) -> None:
        with self._lock:
            self.error = error
            callbacks, self._callbacks = self._callbacks, []
            self._done.set()
        for cb in callbacks:
            try:
                cb(self)
            except Exception:
                pass        # a callback must never wedge graph completion


def _wrap_node_error(name: str, exc: BaseException) -> ExecutionError:
    """Terminal node failure -> graph-level error with node identity."""
    if isinstance(exc, ExecutionError):
        err = ExecutionError(f"graph node {name!r}: {exc}", (),
                             exc.attempts)
        err.records = list(exc.records)
    else:
        err = ExecutionError(
            f"graph node {name!r}: {type(exc).__name__}: {exc}")
    err.node = name  # type: ignore[attr-defined]
    return err


# ---------------------------------------------------------------------------
# Execution driver
# ---------------------------------------------------------------------------

class GraphDriver:
    """Executes one admitted JobGraph over a Scheduler.

    Contract with the scheduler: ``sched.run(sct, env, _resident=...,
    _keep_resident=...)`` is the (thread-safe) node primitive;
    ``sched._graph_pool()`` provides the node thread pool;
    ``sched._graph_done(driver)`` reports completion back to the
    admission queue; ``sched._virtual_busy`` is the shared per-device
    availability map for the virtual-clock path; ``sched._last_slots``
    names the slots of the most recent dispatch (only read on the
    single-threaded virtual path).

    Request options mirror ``Session.run``: ``retries`` terminal-error
    retries per node with exponential backoff, ``deadline`` a whole-
    graph budget in seconds.  Each backoff pause is capped by the
    remaining deadline and a node raises immediately when none remains
    — sleeping past the request deadline is a bug, not a retry.

    Whole-graph plan caching: ``preplanned`` (a topo-ordered list of
    :class:`~repro.core.scheduler.NodePlan`, from a
    ``GraphPlanCache`` hit at submit time) routes every node through
    the scheduler's pre-planned dispatch — no decide-phase lock round
    trip.  On a miss, ``plan_key`` identifies the entry to record: when
    every node completes cleanly (no faults/retries, no distribution
    adjustment, no device-health movement) the driver hands its
    per-node plans back via ``Scheduler._graph_plan_record``.
    """

    def __init__(self, scheduler, handle: GraphHandle,
                 arrays: Dict[str, Any], *,
                 deadline: Optional[float] = None, retries: int = 0,
                 retry_backoff: float = 0.05,
                 preplanned: Optional[List[Any]] = None,
                 plan_key: Optional[Tuple] = None,
                 plan_epoch: int = 0):
        self.sched = scheduler
        self.handle = handle
        self.graph = handle.graph
        self.arrays = dict(arrays)
        self.deadline = deadline
        self.retries = int(retries)
        self.retry_backoff = retry_backoff
        self.preplanned = preplanned
        self.plan_key = plan_key
        self.plan_epoch = plan_epoch
        self._t0 = time.monotonic()
        self._epoch = time.perf_counter()
        self._lock = threading.Lock()
        self._order = self.graph.topo_order()
        self._pos = {n: i for i, n in enumerate(self._order)}
        self._waiting = {n: len(self.graph.deps(n))
                         for n in self.graph.names()}
        self._outputs: Dict[str, Dict[str, Any]] = {}
        self._residents: Dict[str, Any] = {}
        self._errors: Dict[str, BaseException] = {}
        self._settled = 0
        self._n = len(self.graph)

    # -- node primitive (shared by both modes) -------------------------------
    def _keep_resident(self, name: str) -> bool:
        """Residency intent of ``name``'s outgoing edge (chain edges only)."""
        if not getattr(self.sched.executor, "supports_residency", False):
            return False
        node = self.graph.node(name)
        if node.residency is False:
            return False
        succs = self.graph.successors(name)
        return len(succs) == 1 and self.graph.deps(succs[0]) == (name,)

    def _node_env(self, name: str) -> Tuple[Dict[str, Any], Any]:
        """(environment, resident handle) for one ready node.

        The environment layers the graph's input arrays with the merged
        outputs of every *ancestor* (topological order — parallel
        branches never see each other's outputs).  A chain-edge
        dependency that stayed slot-resident is consumed through the
        resident handle instead.
        """
        with self._lock:
            env = dict(self.arrays)
            for anc in self.graph.ancestors(name):
                out = self._outputs.get(anc)
                if out:
                    env.update(out)
            resident = None
            for d in self.graph.deps(name):
                r = self._residents.pop(d, None)
                if r is not None:
                    resident = r
        return env, resident

    def _run_node(self, name: str):
        """One node with per-node retry/deadline semantics; returns the
        ScheduledRun or raises the terminal ExecutionError."""
        node = self.graph.node(name)
        keep = self._keep_resident(name)
        env, resident = self._node_env(name)
        plan = (self.preplanned[self._pos[name]]
                if self.preplanned is not None else None)
        tel = self.sched.telemetry
        last: Optional[ExecutionError] = None
        for k in range(self.retries + 1):
            if self.deadline is not None and \
                    time.monotonic() - self._t0 > self.deadline:
                raise ExecutionError(
                    f"request deadline {self.deadline}s exceeded after "
                    f"{k} attempts", getattr(last, "records", []), k)
            try:
                with tel.tracer.span("node", request=self.handle.request_id,
                                     node=name, retry=k):
                    return self.sched.run(node.sct, env, _resident=resident,
                                          _keep_resident=keep, _plan=plan)
            except ExecutionError as e:
                last = e
                if k == self.retries:
                    raise
                pause = self.retry_backoff * (2 ** k)
                if self.deadline is not None:
                    remaining = self.deadline - (time.monotonic() - self._t0)
                    if remaining <= 0:
                        raise ExecutionError(
                            f"request deadline {self.deadline}s exceeded "
                            f"after {k + 1} attempts", e.records, k + 1)
                    pause = min(pause, remaining)
                if pause > 0:
                    time.sleep(pause)
        raise last  # pragma: no cover — loop always returns or raises

    # -- threaded (concurrent) mode ------------------------------------------
    def start(self) -> None:
        """Admit the graph: schedule every dependency-free node."""
        tel = self.sched.telemetry
        tel.events.emit("graph.admitted", request=self.handle.request_id,
                        nodes=self._n)
        roots = self.graph.roots()
        for name in roots:
            self._dispatch_node(name)
        if not roots:  # pragma: no cover — validate() rejects empty graphs
            self._finalize()

    def _dispatch_node(self, name: str) -> None:
        self.handle._mark(name, "queued")
        self.sched._graph_pool().submit(self._node_main, name)

    def _now_us(self) -> float:
        return (time.perf_counter() - self._epoch) * 1e6

    def _node_main(self, name: str) -> None:
        self.handle._mark(name, "running")
        start_us = self._now_us()
        try:
            run = self._run_node(name)
        except BaseException as e:
            with self.handle._lock:
                self.handle._spans[name] = (start_us, self._now_us())
            self._node_failed(name, e)
            return
        with self.handle._lock:
            self.handle._spans[name] = (start_us, self._now_us())
        self._node_done(name, run)

    def _node_done(self, name: str, run) -> None:
        to_submit: List[str] = []
        with self._lock:
            self.handle.runs[name] = run
            resident = getattr(run, "resident_handle", None)
            if resident is not None:
                self._residents[name] = resident
            if run.outputs:
                self._outputs[name] = run.outputs
            with self.handle._lock:
                self.handle._state[name] = "done"
            self._settled += 1
            for s in self.graph.successors(name):
                self._waiting[s] -= 1
                if self._waiting[s] == 0 and \
                        self.handle._state[s] == "pending":
                    to_submit.append(s)
            finished = self._settled == self._n
        for s in to_submit:
            self._dispatch_node(s)
        if finished:
            self._finalize()

    def _node_failed(self, name: str, exc: BaseException) -> None:
        tel = self.sched.telemetry
        tel.metrics.counter("graph_nodes_failed_total").inc()
        tel.events.emit("graph.node_failed", level="error",
                        request=self.handle.request_id, node=name,
                        message=str(exc))
        with self._lock:
            with self.handle._lock:
                self.handle._state[name] = "failed"
            self._errors[name] = exc
            self._settled += 1
            # containment: descendants are skipped, siblings keep running
            stack = list(self.graph.successors(name))
            while stack:
                s = stack.pop()
                if self.handle._state[s] == "pending":
                    with self.handle._lock:
                        self.handle._state[s] = "skipped"
                    self._settled += 1
                    stack.extend(self.graph.successors(s))
            finished = self._settled == self._n
        if finished:
            self._finalize()

    def _finalize(self) -> None:
        error: Optional[ExecutionError] = None
        for name in self.graph.topo_order():    # deterministic: first in topo
            exc = self._errors.get(name)
            if exc is not None:
                error = _wrap_node_error(name, exc)
                break
        if error is None:
            record = getattr(self.sched, "_graph_plan_record", None)
            if record is not None:
                record(self)
        tel = self.sched.telemetry
        tel.metrics.counter(
            "graphs_total",
            status="error" if error is not None else "ok").inc()
        tel.events.emit("graph.done", request=self.handle.request_id,
                        failed=sum(1 for s in self.handle.status().values()
                                   if s in ("failed", "skipped")))
        self.handle._finish(error)
        self.sched._graph_done(self)

    # -- virtual-clock (simulator) mode --------------------------------------
    def run_virtual(self) -> None:
        """Deterministic graph execution on the simulated timeline.

        Nodes run in topological order; each node becomes *ready* when
        its dependencies end, and each of its slots starts when both the
        node is ready and the slot's device work queue is free — the
        per-device queue model of the threaded executor, replayed in
        virtual time.  Device availability (``sched._virtual_busy``, in
        virtual µs) is shared across submissions, so multi-request
        admission contends realistically.  ``GraphHandle.spans()`` is
        the authoritative node timeline; the simulator's own slot trace
        records each node at its ready time (pure dataflow) and may
        start earlier than the queue-adjusted span.
        """
        ex = self.sched.executor
        busy: Dict[str, float] = self.sched._virtual_busy
        t0v = float(getattr(ex, "vclock_us", 0.0))
        end_us: Dict[str, float] = {}
        for name in self.graph.topo_order():
            deps = self.graph.deps(name)
            if any(self.handle._state[d] != "done" for d in deps):
                self.handle._mark(name, "skipped")
                self._settled += 1
                continue
            ready = max([end_us[d] for d in deps] + [t0v])
            ex.vclock_us = ready
            self.handle._mark(name, "running")
            try:
                run = self._run_node(name)
            except BaseException as e:
                fin = float(ex.vclock_us)
                self.handle._spans[name] = (ready, fin)
                self.handle._state[name] = "failed"
                self._errors[name] = e
                self._settled += 1
                end_us[name] = fin
                self.sched.telemetry.events.emit(
                    "graph.node_failed", level="error",
                    request=self.handle.request_id, node=name,
                    message=str(e))
                continue
            slots = list(getattr(self.sched, "_last_slots", []))
            starts: List[float] = []
            ends: List[float] = []
            for slot, t in zip(slots, run.stats.times):
                if t <= 0:
                    continue        # zero-share slot: no queue occupancy
                s = max(ready, busy.get(slot.device, t0v))
                e_us = s + t * 1e6
                busy[slot.device] = e_us
                starts.append(s)
                ends.append(e_us)
            start_us = min(starts) if starts else ready
            fin_us = max(ends) if ends else float(ex.vclock_us)
            ex.vclock_us = max(fin_us, float(ex.vclock_us))
            self.handle._spans[name] = (start_us, fin_us)
            end_us[name] = fin_us
            self.handle.runs[name] = run
            if run.outputs:
                self._outputs[name] = run.outputs
            self.handle._state[name] = "done"
            self._settled += 1
        self._finalize()
