"""Skeleton Computational Trees (SCTs) — the Marrow library layer in JAX.

A Marrow computation is a tree of skeleton constructions (paper Fig. 1):
``Pipeline``, ``Loop``, ``Map`` and ``MapReduce`` nodes, whose leaves are
``KernelNode`` objects wrapping actual compute kernels.  Per-device
evaluation is depth-first and sequential (paper Sec. 2); across devices
the tree executes under an extended SPMD model where every work partition
runs the whole tree over its slice of the data (paper Sec. 3.1).

TPU adaptation: a *kernel* is any pure JAX function (possibly a Pallas
TPU kernel); ``Loop`` lowers to ``jax.lax.while_loop`` / ``scan``;
``Map`` declares independent-partition semantics (SPMD under GSPMD /
``shard_map``); ``MapReduce`` composes a Map with a device- or host-placed
reduction.  Data flows between kernels through a named environment — two
kernels naming the same vector share an SCT *edge*, which the
locality-aware decomposition keeps resident (sharding-stable) on device.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.spec import ArgSpec, KernelSpec, Trait, Transfer

Env = Dict[str, Any]

_node_counter = itertools.count()


@dataclasses.dataclass
class PartitionInfo:
    """Partition-bound information for Size/Offset traits (paper Sec. 3.4)."""

    size: Any  # elements of the partition along the partition dim
    offset: Any  # offset of the partition w.r.t. the whole domain


class SCT:
    """Base class for every Marrow tree element."""

    name: str

    def apply(self, env: Env) -> Env:
        raise NotImplementedError

    def children(self) -> Sequence["SCT"]:
        return ()

    # -- introspection used by the decomposition / scheduler ---------------
    def kernel_specs(self) -> List[KernelSpec]:
        specs: List[KernelSpec] = []
        for c in self.children():
            specs.extend(c.kernel_specs())
        return specs

    def leaves(self) -> List["KernelNode"]:
        out: List[KernelNode] = []
        for c in self.children():
            out.extend(c.leaves())
        return out

    def free_inputs(self) -> List[ArgSpec]:
        """Vector/scalar args read by the tree before any kernel produces them."""
        produced: set = set()
        free: Dict[str, ArgSpec] = {}
        for leaf in self.leaves():
            for a in leaf.spec.inputs:
                if a.name not in produced and a.name not in free \
                        and a.trait is Trait.NONE:
                    free[a.name] = a
            for a in leaf.spec.outputs:
                produced.add(a.name)
        return list(free.values())

    def output_names(self) -> List[str]:
        names: List[str] = []
        for leaf in self.leaves():
            for a in leaf.spec.outputs:
                if a.name not in names:
                    names.append(a.name)
        return names

    def unique_id(self) -> str:
        """Structural identifier of the SCT (KB key; paper Sec. 3.2.1)."""
        return self._structure()

    def _structure(self) -> str:
        inner = ",".join(c._structure() for c in self.children())
        return f"{type(self).__name__.lower()}({inner})"

    # -- convenience --------------------------------------------------------
    def as_function(self) -> Callable[..., Env]:
        """Pure function env -> env (jit-able)."""
        def fn(env: Env) -> Env:
            return self.apply(dict(env))
        return fn

    def run(self, executor, **arrays):
        """Asynchronous execution request (paper Table 1). Returns a Future."""
        return executor.run(self, **arrays)


class KernelNode(SCT):
    """Leaf node: one computational kernel with a declared interface.

    ``fn`` is a pure function taking the input arguments positionally, in
    ``spec.inputs`` order, and returning one array (or a tuple matching
    ``spec.outputs``).
    """

    def __init__(self, fn: Callable[..., Any], spec: KernelSpec):
        self.fn = fn
        self.spec = spec
        self.name = f"{spec.name}#{next(_node_counter)}"

    def children(self) -> Sequence[SCT]:
        return ()

    def kernel_specs(self) -> List[KernelSpec]:
        return [self.spec]

    def leaves(self) -> List["KernelNode"]:
        return [self]

    def _structure(self) -> str:
        return f"kernel[{self.spec.name}]"

    def apply(self, env: Env) -> Env:
        args = []
        for a in self.spec.inputs:
            if a.trait is Trait.SIZE:
                info: Optional[PartitionInfo] = env.get("__partition__")
                args.append(info.size if info is not None
                            else _domain_size(env, self.spec))
            elif a.trait is Trait.OFFSET:
                info = env.get("__partition__")
                args.append(info.offset if info is not None else 0)
            else:
                if a.name not in env:
                    raise KeyError(
                        f"kernel {self.spec.name}: missing input '{a.name}'")
                args.append(env[a.name])
        out = self.fn(*args)
        if len(self.spec.outputs) == 1:
            out = (out,)
        if len(out) != len(self.spec.outputs):
            raise ValueError(
                f"kernel {self.spec.name} returned {len(out)} outputs, "
                f"spec declares {len(self.spec.outputs)}")
        for a, val in zip(self.spec.outputs, out):
            env[a.name] = val
        return env


def _domain_size(env: Env, spec: KernelSpec):
    for a in spec.inputs:
        if a.partitionable and a.name in env:
            return env[a.name].shape[a.partition_dim]
    return 0


class Pipeline(SCT):
    """Pipeline of control- and data-dependent SCTs (depth-first order)."""

    def __init__(self, *stages: SCT):
        if len(stages) < 1:
            raise ValueError("Pipeline needs at least one stage")
        self.stages = list(stages)
        self.name = f"pipeline#{next(_node_counter)}"

    def children(self) -> Sequence[SCT]:
        return self.stages

    def apply(self, env: Env) -> Env:
        for s in self.stages:
            env = s.apply(env)
        return env


@dataclasses.dataclass
class LoopState:
    """State of a Marrow Loop (paper Sec. 2.1 / 3.1).

    ``init``: extra state variables (name -> array) carried across
    iterations.  ``cond``: traced stoppage condition over the environment
    (stage 1, host-side in the paper; traced into ``while_loop`` here).
    ``update``: state-update applied after each body execution (stage 3).
    ``global_sync``: whether the update requires all-device synchronisation
    (a cross-partition barrier; keeps the Loop's edges replicated).
    ``max_iterations``: when set and ``cond is None`` the loop is a *for*
    loop with a static trip count (lowers to ``lax.scan``-style fori).
    """

    init: Dict[str, Any] = dataclasses.field(default_factory=dict)
    cond: Optional[Callable[[Env], Any]] = None
    update: Optional[Callable[[Env], Env]] = None
    global_sync: bool = False
    max_iterations: Optional[int] = None


class Loop(SCT):
    """*while* / *for* loop over an SCT body."""

    def __init__(self, body: SCT, state: LoopState):
        if state.cond is None and state.max_iterations is None:
            raise ValueError("Loop needs a cond or a max_iterations")
        self.body = body
        self.state = state
        self.name = f"loop#{next(_node_counter)}"

    def children(self) -> Sequence[SCT]:
        return (self.body,)

    def apply(self, env: Env) -> Env:
        env = dict(env)
        env.update(self.state.init)
        env = _ensure_body_outputs(self.body, env, self.state)

        def one_iter(e: Env) -> Env:
            e = self.body.apply(dict(e))
            if self.state.update is not None:
                e = self.state.update(e)
            return e

        if self.state.cond is None:
            # static trip-count for loop
            def body_fun(_, e):
                return one_iter(e)
            return jax.lax.fori_loop(0, self.state.max_iterations, body_fun, env)

        counter_key = "__loop_iters__"
        env[counter_key] = jnp.zeros((), jnp.int32)

        def cond_fun(e):
            ok = self.state.cond(e)
            if self.state.max_iterations is not None:
                ok = jnp.logical_and(ok, e[counter_key] < self.state.max_iterations)
            return ok

        def body_fun(e):
            e = one_iter(e)
            e[counter_key] = e[counter_key] + 1
            return e

        env = jax.lax.while_loop(cond_fun, body_fun, env)
        env.pop(counter_key, None)
        return env


def _ensure_body_outputs(body: SCT, env: Env, state: LoopState) -> Env:
    """Pre-materialise body outputs so the while_loop carry is shape-stable."""
    probe = dict(env)
    shapes = jax.eval_shape(lambda e: body.apply(dict(e)), probe)
    for k, sd in shapes.items():
        if k not in env:
            env[k] = jnp.zeros(sd.shape, sd.dtype)
    return env


class Map(SCT):
    """Application of an SCT upon independent partitions of the input.

    Semantically a marker: the wrapped tree may be partitioned along every
    argument's partition dimension with no cross-partition dependencies.
    Under GSPMD the body simply executes sharded; under the explicit
    ``shard_map`` path the executor runs one body instance per partition.
    """

    def __init__(self, tree: SCT):
        self.tree = tree
        self.name = f"map#{next(_node_counter)}"

    def children(self) -> Sequence[SCT]:
        return (self.tree,)

    def apply(self, env: Env) -> Env:
        return self.tree.apply(env)


class MapReduce(SCT):
    """Map extended with a reduction stage (paper Sec. 2.1).

    The reduction is either another SCT (device-side) or a plain Python /
    jnp function (host-side in the paper; here traced but flagged so the
    decomposition knows the reduce edge crosses partitions).  ``axis``:
    the reduced tensor dimension of the map output.
    """

    def __init__(self, map_stage: SCT,
                 reduction: Union[SCT, Callable[[Any], Any]],
                 *, out_name: Optional[str] = None, axis: int = 0):
        self.map_stage = Map(map_stage) if not isinstance(map_stage, Map) else map_stage
        self.reduction = reduction
        self.axis = axis
        self.out_name = out_name
        self.name = f"mapreduce#{next(_node_counter)}"

    def children(self) -> Sequence[SCT]:
        if isinstance(self.reduction, SCT):
            return (self.map_stage, self.reduction)
        return (self.map_stage,)

    @property
    def host_side_reduction(self) -> bool:
        return not isinstance(self.reduction, SCT)

    def apply(self, env: Env) -> Env:
        env = self.map_stage.apply(env)
        if isinstance(self.reduction, SCT):
            return self.reduction.apply(env)
        # function reduction over the (single) map output
        names = self.map_stage.output_names()
        if len(names) != 1:
            raise ValueError("function-reduction MapReduce requires a single "
                             f"map output, got {names}")
        src = names[0]
        dst = self.out_name or f"{src}_reduced"
        env[dst] = self.reduction(env[src])
        return env


def kernel(fn: Callable[..., Any], *, name: str,
           inputs: Sequence[ArgSpec], outputs: Sequence[ArgSpec],
           work_group_size: Optional[int] = None, work_per_thread: int = 1,
           flops_per_item: float = 1.0, bytes_per_item: float = 4.0,
           local_mem_per_item: float = 0.0) -> KernelNode:
    """Convenience constructor mirroring the paper's ``OpenCLKernel``."""
    spec = KernelSpec(name=name, inputs=tuple(inputs), outputs=tuple(outputs),
                      work_group_size=work_group_size,
                      work_per_thread=work_per_thread,
                      flops_per_item=flops_per_item,
                      bytes_per_item=bytes_per_item,
                      local_mem_per_item=local_mem_per_item)
    return KernelNode(fn, spec)
