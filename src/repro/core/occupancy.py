"""Kernel-occupancy model — work-group sizing for the TPU (paper Sec. 3.1).

The paper computes GPU kernel occupancy from the usual constraining
factors: work-groups per compute unit, local memory per work-group, and
registers per thread; the autotuner then orders candidate work-group sizes
by non-increasing occupancy and filters those under a configurable
threshold (default 80%).

TPU adaptation.  The work-group analogue is a **compute block**: the tile
a Pallas kernel (or an XLA fusion) processes per grid step.  The occupancy
constraints become:

  * VMEM footprint — the block's working set (inputs + outputs + scratch,
    ``local_mem_per_item`` bytes/element) must fit the ~128 MiB/core VMEM
    budget, with double-buffering doubling the input footprint;
  * MXU alignment — matmul-feeding dimensions should be multiples of the
    128x128 systolic array (8x128 VPU lanes for elementwise work);
  * grid parallelism — enough blocks to cover all cores (the
    work-groups-per-CU analogue).

``occupancy(wgs)`` returns a 0..1 score combining the three; ``candidates``
yields hardware-valid block sizes ordered exactly as Algorithm 1 consumes
them (non-increasing occupancy).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.spec import KernelSpec

# TPU v5e per-core constants (target hardware; see DESIGN.md Sec. 2)
VMEM_BYTES = 128 * 1024 * 1024
MXU_DIM = 128          # systolic array edge
VPU_LANES = 8 * 128    # sublane x lane
DEFAULT_THRESHOLD = 0.80


@dataclasses.dataclass(frozen=True)
class BlockScore:
    wgs: int
    occupancy: float
    vmem_bytes: int
    aligned: bool


def _vmem_footprint(spec: KernelSpec, wgs: int) -> int:
    """Working-set bytes of one block (double-buffered inputs)."""
    n_vec = max(1, len(spec.vectors))
    per_elem = spec.bytes_per_item * n_vec + spec.local_mem_per_item
    return int(wgs * spec.work_per_thread * per_elem * 2)


def occupancy(spec: KernelSpec, wgs: int, *, grid_blocks: int = 1,
              cores: int = 1) -> BlockScore:
    if wgs < 1:
        raise ValueError("wgs must be >= 1")
    vmem = _vmem_footprint(spec, wgs)
    vmem_score = min(1.0, VMEM_BYTES / max(vmem, 1))
    if vmem > VMEM_BYTES:
        vmem_score = VMEM_BYTES / vmem       # over budget -> penalised < 1
    else:
        # under budget is fine, but *tiny* blocks waste the memory pipeline:
        # score the utilisation of one double-buffered VPU-aligned stripe.
        vmem_score = min(1.0, (wgs * spec.work_per_thread) / VPU_LANES)
    aligned = (wgs % MXU_DIM == 0) or (wgs % VPU_LANES == 0)
    align_score = 1.0 if aligned else 0.5 + 0.5 * (wgs % MXU_DIM == 0)
    par_score = min(1.0, grid_blocks / cores)
    occ = vmem_score * align_score * par_score
    return BlockScore(wgs=wgs, occupancy=min(occ, 1.0),
                      vmem_bytes=vmem, aligned=aligned)


def candidates(spec: KernelSpec, domain_size: int, *, cores: int = 1,
               threshold: float = DEFAULT_THRESHOLD,
               max_candidates: int = 12) -> List[BlockScore]:
    """Valid block sizes in non-increasing occupancy order (paper filter).

    If no candidate clears the threshold the best-occupancy one is
    returned alone (paper footnote 2).
    """
    if spec.work_group_size is not None:
        # kernel is bound to a particular size (paper Sec. 2.1)
        blocks = max(1, domain_size // max(spec.work_group_size, 1))
        return [occupancy(spec, spec.work_group_size,
                          grid_blocks=blocks, cores=cores)]
    sizes: List[int] = []
    w = MXU_DIM
    while w <= max(domain_size, MXU_DIM) and len(sizes) < max_candidates * 2:
        if w <= domain_size or not sizes:
            sizes.append(min(w, max(domain_size, 1)))
        w *= 2
    scored = []
    for s in dict.fromkeys(sizes):
        blocks = max(1, domain_size // max(s, 1))
        scored.append(occupancy(spec, s, grid_blocks=blocks, cores=cores))
    scored.sort(key=lambda b: (-b.occupancy, -b.wgs))
    ok = [b for b in scored if b.occupancy >= threshold]
    return (ok or scored[:1])[:max_candidates]
