"""Kernel interface specification — the Marrow `IDataType` layer.

The paper (Sec. 2.1, 3.4) requires every kernel wrapped in an SCT to declare
its interface: which arguments are vectors vs scalars, which are immutable,
which may be partitioned across devices (and with which *elementary
partitioning unit*, ``epu``), and which must be replicated (``COPY``
transfer mode).  Scalar parameters may carry partition-bound traits
(``Size`` / ``Offset``).

These declarations drive the locality-aware domain decomposition
(:mod:`repro.core.decomposition`).
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Any, Callable, Optional, Sequence, Tuple


class Trait(enum.Enum):
    """Partition-bound scalar traits (paper Sec. 3.4)."""

    NONE = "none"
    SIZE = "size"      # instantiated with the size of the current partition
    OFFSET = "offset"  # instantiated with the partition's offset in the domain


class Transfer(enum.Enum):
    """Data-transfer mode for vector arguments."""

    PARTITION = "partition"  # locality-aware partitioning (default)
    COPY = "copy"            # replicate integrally to all devices


@dataclasses.dataclass(frozen=True)
class ArgSpec:
    """Specification of one kernel argument.

    Attributes:
      name: argument name (used to identify shared edges between kernels).
      kind: "vector" or "scalar".
      mutable: whether the kernel writes the argument.
      transfer: PARTITION or COPY (vectors only).
      partition_dim: tensor dimension along which partitioning happens.
      epu: elementary partitioning unit, in elements along ``partition_dim``
        (paper: image line, FFT block, plane of a 3-D volume, ...).
      trait: SIZE/OFFSET for partition-bound scalars.
    """

    name: str
    kind: str = "vector"
    mutable: bool = False
    transfer: Transfer = Transfer.PARTITION
    partition_dim: int = 0
    epu: int = 1
    trait: Trait = Trait.NONE

    def __post_init__(self) -> None:
        if self.kind not in ("vector", "scalar"):
            raise ValueError(f"bad ArgSpec.kind: {self.kind}")
        if self.epu < 1:
            raise ValueError("epu must be >= 1")

    @property
    def partitionable(self) -> bool:
        return self.kind == "vector" and self.transfer is Transfer.PARTITION


def vector(name: str, *, mutable: bool = False, partition_dim: int = 0,
           epu: int = 1, copy: bool = False) -> ArgSpec:
    return ArgSpec(name=name, kind="vector", mutable=mutable,
                   transfer=Transfer.COPY if copy else Transfer.PARTITION,
                   partition_dim=partition_dim, epu=epu)


def scalar(name: str, *, trait: Trait = Trait.NONE) -> ArgSpec:
    return ArgSpec(name=name, kind="scalar", trait=trait)


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Interface of a computational kernel (paper Sec. 2.1).

    ``work_per_thread`` is the paper's ``nu(V, K)``: how many elements of
    the partition dimension one work-item computes.  ``work_group_size``
    is an optional hard work-group requirement; when ``None`` the tuner is
    free to choose one (from the occupancy-ordered candidate list).

    ``flops_per_item`` / ``bytes_per_item`` feed the occupancy and roofline
    models; ``local_mem_per_item`` is the VMEM (TPU) analogue of OpenCL
    local memory, in bytes per element of a work-group's tile.
    """

    name: str
    inputs: Tuple[ArgSpec, ...]
    outputs: Tuple[ArgSpec, ...]
    work_group_size: Optional[int] = None
    work_per_thread: int = 1
    flops_per_item: float = 1.0
    bytes_per_item: float = 4.0
    local_mem_per_item: float = 0.0

    def arg(self, name: str) -> ArgSpec:
        for a in self.inputs + self.outputs:
            if a.name == name:
                return a
        raise KeyError(name)

    @property
    def vectors(self) -> Tuple[ArgSpec, ...]:
        return tuple(a for a in self.inputs + self.outputs if a.kind == "vector")

    def nu(self, arg_name: str) -> int:
        """Paper's nu(V, K): elements of V computed per work-item."""
        _ = self.arg(arg_name)
        return self.work_per_thread


@dataclasses.dataclass(frozen=True)
class Workload:
    """Characterisation of a workload (paper Sec. 3.2.1).

    ``dims``: number of elements per dimension of the work space.
    ``double_precision``: whether the data is fp64 (paper) — we generalise
    to an ``itemsize`` in bytes.
    """

    dims: Tuple[int, ...]
    itemsize: int = 4

    @property
    def ndim(self) -> int:
        return len(self.dims)

    @property
    def size(self) -> int:
        return int(math.prod(self.dims))

    def as_features(self) -> Tuple[float, ...]:
        """Feature vector for KB interpolation (dims + precision flag)."""
        return tuple(float(d) for d in self.dims) + (float(self.itemsize),)

    def key(self) -> str:
        return "x".join(str(d) for d in self.dims) + f"@{self.itemsize}"


MergeFn = Callable[[Sequence[Any]], Any]

#: Predefined merging functions (paper Sec. 3.4): addition, subtraction,
#: multiplication and division over the partial results of partitions.
#:
#: Fault-tolerance note: under repartition-retry (repro.core.faults) a
#: failed partition's partial result is replaced by *several* partial
#: results from the sub-ranges adopted by surviving slots, so a MergeFn
#: must tolerate a variable number of parts.  ADD/MUL are fully safe
#: (associative + commutative); the left-fold SUB/DIV semantics
#: ``p0 - p1 - ... = p0 - (p1 + ...)`` survive re-splits of any
#: partition except the first — custom non-associative merges should be
#: paired with ``FaultPolicy(max_attempts=1)``.
MERGE_ADD: MergeFn = lambda parts: _fold(parts, lambda a, b: a + b)
MERGE_SUB: MergeFn = lambda parts: _fold(parts, lambda a, b: a - b)
MERGE_MUL: MergeFn = lambda parts: _fold(parts, lambda a, b: a * b)
MERGE_DIV: MergeFn = lambda parts: _fold(parts, lambda a, b: a / b)


def _fold(parts: Sequence[Any], op: Callable[[Any, Any], Any]) -> Any:
    acc = parts[0]
    for p in parts[1:]:
        acc = op(acc, p)
    return acc
