"""Profile construction — the paper's Algorithm 1 (Sec. 3.2.2).

Searches the configuration space

    (CPU fission level) x (GPU overlap factor) x (per-kernel work-group
    sizes) x (CPU/GPU workload distribution)

for the globally best-performing tuple.  The search is *ordered* and
*pruned* exactly as in the paper:

  * fission levels are tried L1 -> ... -> NO_FISSION,
  * overlap factors in natural order 1, 2, ...,
  * work-group sizes in non-increasing occupancy order (threshold-filtered),
  * whenever a candidate value fails to improve on the previous one, all
    subsequent values of that dimension are **discarded**,
  * the inner workload-distribution loop is the binary-search generator,
    stopped when two consecutive overall times differ by less than
    ``precision``,
  * each timed point is the best of ``number_executions`` runs (the
    paper's quality factor against performance fluctuations).

The evaluator is injected: the *real* executor times actual partitioned
executions on this host; the *simulator* (benchmarks reproducing the
paper's figures) and the *roofline evaluator* (TPU sharding hillclimb,
Sec. Perf) implement the same callable interface.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.distribution import Distribution, WorkloadDistributionGenerator
from repro.core.knowledge_base import (KnowledgeBase, Origin, PlatformConfig,
                                       Profile)
from repro.core.occupancy import BlockScore
from repro.core.platforms import AcceleratorPlatform, HostPlatform
from repro.core.spec import Workload

#: evaluator(config, distribution) -> (total_time, time_a, time_b)
Evaluator = Callable[[PlatformConfig, Distribution], Tuple[float, float, float]]


@dataclasses.dataclass
class TunerParams:
    occupancy_threshold: float = 0.80
    precision: float = 0.02          # seconds (or simulator units)
    number_executions: int = 3
    max_distribution_iters: int = 12


@dataclasses.dataclass
class TraceEntry:
    """One timed configuration — Fig. 5 is a plot of these."""

    fission_level: str
    overlap: int
    wgs: Dict[str, int]
    distribution: float              # share of class a
    time: float


@dataclasses.dataclass
class TuneResult:
    profile: Profile
    trace: List[TraceEntry]
    evaluations: int


def _wgs_product(per_kernel: Dict[str, List[BlockScore]]
                 ) -> List[Dict[str, int]]:
    """Candidate work-group assignments, best-occupancy-first.

    Rather than the full cartesian product (exponential), Algorithm 1's
    ordered-and-discardable iteration is realised rank-by-rank: rank k
    assigns every kernel its k-th best block size (clamped), which yields
    the same non-increasing-occupancy order the paper prescribes.
    """
    if not per_kernel:
        return [{}]
    depth = max(len(v) for v in per_kernel.values())
    out = []
    for k in range(depth):
        out.append({name: scores[min(k, len(scores) - 1)].wgs
                    for name, scores in per_kernel.items()})
    # dedupe consecutive identical assignments
    uniq: List[Dict[str, int]] = []
    for a in out:
        if not uniq or a != uniq[-1]:
            uniq.append(a)
    return uniq


def build_profile(sct_id: str, workload: Workload, *,
                  host: HostPlatform, accel: AcceleratorPlatform,
                  evaluate: Evaluator, params: TunerParams = TunerParams(),
                  kb: Optional[KnowledgeBase] = None,
                  sct=None) -> TuneResult:
    """Algorithm 1.  Returns the best profile plus the full search trace."""
    trace: List[TraceEntry] = []
    evals = 0
    best_profile = Profile(sct_id=sct_id, workload=workload, share_a=1.0,
                           config=PlatformConfig(), best_time=math.inf,
                           origin=Origin.BUILT)

    cpu_configurations = host.get_configurations(sct, None)           # step 1
    overlaps, wgs_cands = accel.get_configurations(                   # step 2
        sct, None, domain_size=workload.size)
    wgs_assignments = _wgs_product(wgs_cands)                         # step 3

    prev_fission_best = math.inf
    for fission in cpu_configurations:
        host.configure(fission.level)                                 # step 5
        prev_overlap_best = math.inf
        fission_best = math.inf
        for overlap in overlaps:
            accel.configure(overlap)                                  # step 7
            prev_wgs_best = math.inf
            overlap_best = math.inf
            for wgs in wgs_assignments:
                cfg = PlatformConfig(fission_level=fission.level,
                                     overlap=overlap, wgs=dict(wgs))
                wldg = WorkloadDistributionGenerator()                # step 9
                wgs_best = math.inf
                prev_time = math.inf
                for _ in range(params.max_distribution_iters):
                    dist = wldg.next()                                # step 11
                    # steps 12-13: partition + execute (best of N)
                    total, ta, tb = math.inf, math.inf, math.inf
                    for _ in range(params.number_executions):
                        t, a, b = evaluate(cfg, dist)
                        if t < total:
                            total, ta, tb = t, a, b
                    evals += 1
                    trace.append(TraceEntry(fission.level, overlap, dict(wgs),
                                            dist.a, total))
                    wldg.feedback(ta, tb)
                    wgs_best = min(wgs_best, total)
                    if total < best_profile.best_time:                # 15-16
                        best_profile = Profile(
                            sct_id=sct_id, workload=workload, share_a=dist.a,
                            config=cfg, best_time=total, origin=Origin.BUILT)
                    if abs(prev_time - total) < params.precision:     # step 17
                        break
                    prev_time = total
                overlap_best = min(overlap_best, wgs_best)
                if wgs_best >= prev_wgs_best:                         # step 21
                    break                                             # discard
                prev_wgs_best = wgs_best
            fission_best = min(fission_best, overlap_best)
            if overlap_best >= prev_overlap_best:                     # step 23
                break
            prev_overlap_best = overlap_best
        if fission_best >= prev_fission_best:                         # step 25
            break
        prev_fission_best = fission_best

    if kb is not None:
        kb.store(best_profile)                                        # persist
    return TuneResult(profile=best_profile, trace=trace, evaluations=evals)
