"""Workload distribution between device classes (paper Sec. 3.2.2 / 3.3.1).

Two search procedures over the CPU/GPU (here: slow-class/fast-class) split:

* :class:`WorkloadDistributionGenerator` — the paper's *binary search*.
  At every step the **transferable partition** is split evenly between the
  two device types; after observing which type finished first, the half
  assigned to the winner is *permanently bound* to it and the other half
  becomes the next transferable partition:

      transferableSize(n, size) = size / 2^n,  ->  0 as n -> inf

* :class:`AdaptiveBinarySearch` — the load-balancing variant (Sec. 3.3.1).
  The interval under inspection may *shift sideways* when the optimum has
  moved out of it (CPU load fluctuation), and after more than 2 shifts in
  the same direction the transferable partition **doubles** to speed up the
  chase of the new optimum.

Device classes are kept abstract ("a" = accelerator-like / GPU, "b" =
host-like / CPU in the paper; on the TPU adaptation they are fast/slow
slice classes of a heterogeneous pool).  Within one class, load is divided
*statically* by the per-device performance ratios measured at installation
time (paper: SHOC suite; here :mod:`repro.core.platforms`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class Distribution:
    """A workload split: fraction of the domain per device type."""

    a: float  # fast class (GPU in the paper)
    b: float  # slow class (CPU in the paper)

    def __post_init__(self) -> None:
        if not (-1e-9 <= self.a <= 1 + 1e-9 and -1e-9 <= self.b <= 1 + 1e-9):
            raise ValueError(f"bad distribution ({self.a}, {self.b})")
        if abs(self.a + self.b - 1.0) > 1e-6:
            raise ValueError(f"distribution must sum to 1, got {self.a + self.b}")
        self.a = min(1.0, max(0.0, self.a))
        self.b = 1.0 - self.a

    def per_device(self, ratios_a: Sequence[float],
                   ratios_b: Sequence[float]) -> List[float]:
        """Static intra-class split by relative performance (paper Sec. 3.2).

        ``ratios_*``: one positive throughput score per device of the class
        (from install-time calibration).  Returns one share per device,
        class-a devices first.
        """
        out: List[float] = []
        for frac, ratios in ((self.a, ratios_a), (self.b, ratios_b)):
            tot = sum(ratios)
            if ratios and tot <= 0:
                raise ValueError("non-positive calibration ratios")
            out.extend(frac * r / tot for r in ratios)
        if not out:
            raise ValueError("no devices")
        return out


@dataclasses.dataclass
class _Step:
    dist: Distribution
    time_a: float
    time_b: float


class WorkloadDistributionGenerator:
    """Paper Sec. 3.2.2: binary-search workload distribution generator.

    Iterator protocol:
      >>> g = WorkloadDistributionGenerator()
      >>> d = g.next()                # candidate distribution
      >>> g.feedback(time_a, time_b)  # observed per-class completion times
      >>> d = g.next()                # refined candidate ...

    Internally tracks ``bound_a``/``bound_b`` (work permanently bound to a
    class) and ``transferable`` (work still under training).  Each candidate
    assigns every class its bound share plus half the transferable one.
    """

    def __init__(self, initial: Optional[Distribution] = None):
        if initial is None:
            self.bound_a = 0.0
            self.bound_b = 0.0
            self.transferable = 1.0
        else:
            # Warm start (used by the load balancer): treat the current
            # distribution as mostly bound, with a small transferable margin.
            self.transferable = 2 * min(initial.a, initial.b, 0.25)
            self.bound_a = initial.a - self.transferable / 2
            self.bound_b = initial.b - self.transferable / 2
        self.history: List[_Step] = []
        self._pending: Optional[Distribution] = None

    @property
    def iteration(self) -> int:
        return len(self.history)

    def transferable_size(self) -> float:
        """Paper: transferableSize(n, 1.0) = 1 / 2^n (cold start)."""
        return self.transferable

    def next(self) -> Distribution:
        d = Distribution(a=self.bound_a + self.transferable / 2,
                         b=self.bound_b + self.transferable / 2)
        self._pending = d
        return d

    def feedback(self, time_a: float, time_b: float) -> None:
        """Bind half the transferable partition to the faster class."""
        if self._pending is None:
            raise RuntimeError("feedback() without a pending next()")
        half = self.transferable / 2
        if time_a <= time_b:      # class a finished first -> bind to a
            self.bound_a += half
        else:
            self.bound_b += half
        self.transferable = half
        self.history.append(_Step(self._pending, time_a, time_b))
        self._pending = None

    def converged(self, precision: float) -> bool:
        """Stop when two consecutive candidates differ less than precision."""
        return self.transferable < precision


class AdaptiveBinarySearch:
    """Paper Sec. 3.3.1: binary search whose interval may shift sideways.

    Used by the dynamic load balancer.  Starts from the currently-persisted
    distribution.  Each round proposes a distribution; ``feedback`` moves
    load from the worst- to the best-performing class.  If the winner stays
    on the same side the interval *shifts* in that direction; after more
    than ``shift_doubling`` (=2) consecutive shifts in one direction the
    transferable partition doubles, speeding up convergence towards a far
    optimum (the "shifting phase" of Fig. 11).  Once the winner alternates,
    the procedure degenerates into the plain halving binary search.
    """

    def __init__(self, current: Distribution, *, step: float = 0.05,
                 shift_doubling: int = 2, max_step: float = 0.5):
        self.center = current
        self.transferable = step
        self.max_step = max_step
        self.shift_doubling = shift_doubling
        self._consecutive = 0          # signed count of same-direction shifts
        self._last_winner: Optional[str] = None
        self._pending: Optional[Distribution] = None
        self.history: List[_Step] = []

    def next(self) -> Distribution:
        self._pending = self.center
        return self.center

    def feedback(self, time_a: float, time_b: float) -> Distribution:
        if self._pending is None:
            raise RuntimeError("feedback() without a pending next()")
        winner = "a" if time_a < time_b else "b"
        if winner == self._last_winner:
            self._consecutive += 1
        else:
            self._consecutive = 1
            # direction flipped: enter plain binary search (halve the step)
            if self._last_winner is not None:
                self.transferable = max(self.transferable / 2, 1e-4)
        self._last_winner = winner

        # >2 shifts in the same direction -> double the transferable size
        if self._consecutive > self.shift_doubling:
            self.transferable = min(self.transferable * 2, self.max_step)

        delta = self.transferable
        if winner == "a":   # a faster -> move work towards a
            new_a = min(1.0, self.center.a + delta)
        else:
            new_a = max(0.0, self.center.a - delta)
        self.history.append(_Step(self._pending, time_a, time_b))
        self.center = Distribution(a=new_a, b=1.0 - new_a)
        self._pending = None
        return self.center

    def converged(self, precision: float) -> bool:
        return self.transferable < precision


def run_binary_search(measure, *, precision: float = 0.01,
                      max_iters: int = 32) -> Tuple[Distribution, int]:
    """Drive a cold-start binary search to convergence.

    ``measure(dist) -> (time_a, time_b)`` executes (or simulates) the SCT
    under the candidate distribution.  Returns the final distribution and
    the number of iterations used.
    """
    g = WorkloadDistributionGenerator()
    d = g.next()
    for it in range(max_iters):
        ta, tb = measure(d)
        g.feedback(ta, tb)
        if g.converged(precision):
            break
        d = g.next()
    return g.next(), g.iteration


def balance_until_stable(measure, current: Distribution, *,
                         precision: float = 0.005, max_iters: int = 64,
                         step: float = 0.05) -> Tuple[Distribution, int]:
    """Drive the adaptive binary search until its step is below precision."""
    s = AdaptiveBinarySearch(current, step=step)
    d = s.next()
    for it in range(max_iters):
        ta, tb = measure(d)
        d = s.feedback(ta, tb)
        if s.converged(precision):
            break
        s.next()
    return s.center, len(s.history)
