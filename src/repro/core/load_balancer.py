"""Dynamic load balancing (paper Sec. 3.3).

Every SCT execution is monitored; per-execution statistics feed the
*load-balancing threshold*:

    lbt(n) = isUnbalanced(dev) * weight + lbt(n-1) * (1 - weight)

    isUnbalanced(x) = 0  if x / cFactor <= maxDev
                      1  otherwise

where ``dev`` is the deviation between the completion times of the
concurrent executions of the SCT, ``weight`` the weight of the last run
versus history (default 2/3 per the paper — 3-to-4 consecutive unbalanced
runs trigger balancing), ``maxDev`` the user bound (paper Table 4
calibrates [0.8, 0.85]) and ``cFactor`` a correction for computations that
prefer slightly unbalanced distributions.

A SCT is *unbalanced* when ``lbt(n) ~ 1``; the balancer then adjusts the
distribution with the :class:`~repro.core.distribution.AdaptiveBinarySearch`
and persists improved configurations back into the KB (progressive profile
refinement).

Deviation convention: times t_1..t_p of the p concurrent executions give
``dev = min(t) / max(t)`` (1.0 = perfectly balanced), matching Table 4's
"all executions within 80..85% of the best performing one".  A run is
unbalanced when ``dev / cFactor < maxDev`` — the formula above with the
comparison inverted to match this convention.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.core.distribution import AdaptiveBinarySearch, Distribution
from repro.core.telemetry import NULL_TELEMETRY


@dataclasses.dataclass
class ExecutionStats:
    """Statistics of one monitored SCT execution (paper Sec. 3.3).

    ``time_a`` / ``time_b`` are the per-class makespans (accelerator
    class first) recorded at dispatch time so the balancer, the
    autotuner's evaluator, and the device-health tracker all share one
    source of truth.  ``failures`` / ``retries`` carry the fault history
    of the run (see :mod:`repro.core.faults`): a run with failures is
    excluded from lbt updates and KB ``best_time`` refinement so fault
    noise cannot corrupt learned profiles.

    The per-phase breakdown decomposes one scheduled run's wall time:
    ``plan_seconds`` (decomposition-plan derivation + partitioning, or a
    plan-cache lookup), ``pool_seconds`` (worker-pool acquisition; ~0
    when the persistent pool is reused), ``dispatch_seconds`` (segment
    setup and task launch), ``compute_seconds`` (the concurrent kernel
    attempts) and ``merge_seconds`` (result assembly).  ``merge_bytes``
    counts bytes copied at merge time — 0 on the resident-chain path and
    whenever every partitionable output was written in place by its
    slot.  ``plan_cache_hit`` / ``resident`` flag which fast paths the
    run took.
    """

    times: List[float]           # per concurrent execution
    share_a: float               # distribution in effect
    time_a: float = 0.0          # accelerator-class makespan
    time_b: float = 0.0          # host-class makespan
    failures: List = dataclasses.field(default_factory=list)  # FaultRecords
    retries: int = 0             # repartition/retry rounds consumed
    plan_seconds: float = 0.0    # plan build/partition (or cache lookup)
    pool_seconds: float = 0.0    # worker-pool creation/acquisition
    dispatch_seconds: float = 0.0  # segment setup + task launch
    compute_seconds: float = 0.0   # concurrent kernel execution (wall)
    merge_seconds: float = 0.0   # result assembly
    merge_bytes: int = 0         # bytes copied during merge (0 = zero-copy)
    plan_cache_hit: bool = False  # partitioning served from the plan cache
    resident: bool = False       # outputs left slot-resident (merge skipped)

    @property
    def ok(self) -> bool:
        return not self.failures

    @property
    def total(self) -> float:
        return max(self.times) if self.times else 0.0

    @property
    def overhead_seconds(self) -> float:
        """Non-compute dispatch overhead: plan + pool + dispatch + merge."""
        return (self.plan_seconds + self.pool_seconds
                + self.dispatch_seconds + self.merge_seconds)

    @property
    def deviation(self) -> float:
        if not self.times or max(self.times) <= 0:
            return 1.0
        return min(self.times) / max(self.times)


class LoadBalancer:
    """lbt-based unbalance detector + adaptive-binary-search corrector."""

    def __init__(self, *, max_dev: float = 0.85, weight: float = 2.0 / 3.0,
                 c_factor: float = 1.0, trigger: float = 0.9):
        if not 0 < weight <= 1:
            raise ValueError("weight in (0, 1]")
        self.max_dev = max_dev
        self.weight = weight
        self.c_factor = c_factor
        self.trigger = trigger          # lbt(n) ~ 1 -> balance
        self.lbt = 0.0
        self.unbalanced_runs = 0
        self.balance_ops = 0
        self.telemetry = NULL_TELEMETRY
        self._search: Optional[AdaptiveBinarySearch] = None

    # -- detector -------------------------------------------------------------
    def is_unbalanced(self, deviation: float) -> bool:
        return (deviation / self.c_factor) < self.max_dev

    def observe(self, stats: ExecutionStats) -> bool:
        """Update lbt with one execution; True if balancing should kick in.

        Runs that suffered slot faults are ignored: their per-slot times
        mix real compute with retry/repartition noise, so feeding them to
        the detector would trigger spurious balancing operations.
        """
        if not stats.ok:
            return False
        ub = 1.0 if self.is_unbalanced(stats.deviation) else 0.0
        if ub:
            self.unbalanced_runs += 1
            self.telemetry.metrics.counter("balancer_unbalanced_total").inc()
        self.lbt = ub * self.weight + self.lbt * (1.0 - self.weight)
        self.telemetry.metrics.gauge("balancer_lbt").set(self.lbt)
        triggered = self.lbt >= self.trigger
        if triggered:
            self.telemetry.events.emit(
                "balancer.trigger", lbt=round(self.lbt, 6),
                deviation=round(stats.deviation, 6),
                share_a=stats.share_a)
        return triggered

    # -- corrector --------------------------------------------------------------
    def adjust(self, current: Distribution, stats_a: float, stats_b: float,
               *, step: float = 0.05) -> Distribution:
        """One load-balancing operation: move work from worst to best class.

        ``stats_a`` / ``stats_b`` are the per-class completion times of the
        last run.  Keeps the adaptive search alive across calls so the
        shifting/doubling behaviour (Fig. 11) spans consecutive
        adjustments; the search restarts when balance has been re-attained
        (lbt back under trigger).
        """
        if self._search is None:
            self._search = AdaptiveBinarySearch(current, step=step)
            self._search.next()
        else:
            # re-anchor at the externally persisted distribution
            self._search.center = current
            self._search.next()
        new = self._search.feedback(stats_a, stats_b)
        self.balance_ops += 1
        self.telemetry.metrics.counter("balancer_adjustments_total").inc()
        self.telemetry.events.emit(
            "balancer.adjust", share_a_before=round(current.a, 6),
            share_a_after=round(new.a, 6), time_a=stats_a, time_b=stats_b)
        return new

    def reset_search(self) -> None:
        self._search = None

    def balanced_again(self) -> None:
        """Called when an execution round is balanced: cool down."""
        if self.lbt < self.trigger:
            self._search = None


def class_times(times: Sequence[float], n_a: int) -> tuple:
    """Split per-execution times into per-class makespans (a first)."""
    ta = max(times[:n_a]) if n_a else 0.0
    tb = max(times[n_a:]) if len(times) > n_a else 0.0
    return ta, tb
