"""Task launcher / executor — real partitioned execution on this host.

The Scheduler produces a :class:`ConcretePartitioning`; the executor turns
it into a group of tasks (one per execution slot, paper Fig. 2/3), places
them in per-slot work queues (a thread pool here), runs the SCT over each
partition, and merges the partial results:

  * partitionable outputs — concatenated along their partition dimension
    (the partitions tile the domain, paper Sec. 3.1);
  * COPY / replicated outputs — taken from the first slot;
  * reduced outputs — combined with the kernel-declared or user-supplied
    *merging function* (paper Sec. 3.4; MERGE_ADD & friends).

``Size`` / ``Offset`` traits are bound per-slot through the environment's
``__partition__`` entry.

This is the measurement backend for CPU-side experiments (fission table);
scheduling-policy experiments at device-pool scale use the calibrated
:mod:`repro.core.simulator` instead (same interface).
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.decomposition import ConcretePartitioning
from repro.core.knowledge_base import Profile
from repro.core.skeletons import SCT, PartitionInfo
from repro.core.spec import ArgSpec, MergeFn, Transfer, Workload


def output_spec(sct: SCT, name: str) -> Optional[ArgSpec]:
    for leaf in sct.leaves():
        for a in leaf.spec.outputs:
            if a.name == name:
                return a
    return None


@dataclasses.dataclass
class _SlotResult:
    outputs: Dict[str, Any]
    seconds: float


class ThreadedExecutor:
    """Executes SCT partitions on host threads and times each slot."""

    def __init__(self, *, merges: Optional[Dict[str, MergeFn]] = None,
                 max_workers: Optional[int] = None):
        self.merges = dict(merges or {})
        self.max_workers = max_workers
        self._last_times: List[float] = []
        self._last_n_a: int = 0

    # -- Scheduler interface -------------------------------------------------
    def execute(self, sct: SCT, part: ConcretePartitioning,
                arrays: Dict[str, Any], profile: Profile
                ) -> Tuple[Dict[str, Any], List[float]]:
        plan = part.plan
        witness = next((v.name for v in plan.vectors.values() if not v.copy),
                       None)
        slot_envs: List[Dict[str, Any]] = []
        for j, slot in enumerate(part.slots):
            env: Dict[str, Any] = {}
            for name, arr in arrays.items():
                if name in plan.vectors:
                    env[name] = part.slices(name, arr)[j]
                else:
                    env[name] = arr         # scalars & undeclared passthrough
            if witness is not None:
                env["__partition__"] = PartitionInfo(
                    size=part.sizes(witness)[j],
                    offset=part.offsets(witness)[j])
            slot_envs.append(env)

        results: List[Optional[_SlotResult]] = [None] * len(part.slots)

        def work(j: int) -> None:
            t0 = time.perf_counter()
            out_env = sct.apply(dict(slot_envs[j]))
            for v in out_env.values():
                if hasattr(v, "block_until_ready"):
                    v.block_until_ready()
            results[j] = _SlotResult(out_env, time.perf_counter() - t0)

        nw = self.max_workers or len(part.slots)
        if len(part.slots) == 1:
            work(0)
        else:
            with cf.ThreadPoolExecutor(max_workers=nw) as pool:
                list(pool.map(work, range(len(part.slots))))

        outputs = self._merge(sct, part, [r.outputs for r in results])
        times = [r.seconds for r in results]
        self._last_times = times
        self._last_n_a = sum(1 for s in part.slots if s.device_type != "cpu")
        return outputs, times

    def last_class_times(self) -> Tuple[float, float]:
        n_a = self._last_n_a
        t = self._last_times
        ta = max(t[:n_a]) if n_a else 0.0
        tb = max(t[n_a:]) if len(t) > n_a else 0.0
        return ta, tb

    def synthesise_arrays(self, sct: SCT, workload: Workload
                          ) -> Dict[str, Any]:
        """Random arrays matching a workload (Algorithm 1 evaluations)."""
        rng = np.random.default_rng(0)
        out: Dict[str, Any] = {}
        for a in sct.free_inputs():
            if a.kind == "scalar":
                out[a.name] = np.float32(1.0)
            else:
                out[a.name] = rng.standard_normal(workload.dims
                                                  ).astype(np.float32)
        return out

    # -- merging ---------------------------------------------------------------
    def _merge(self, sct: SCT, part: ConcretePartitioning,
               envs: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
        merged: Dict[str, Any] = {}
        for name in _produced_names(sct):
            parts = [e[name] for e in envs if name in e]
            if not parts:
                continue
            if name in self.merges:
                merged[name] = self.merges[name](parts)
                continue
            spec = output_spec(sct, name)
            vp = part.plan.vectors.get(name)
            if vp is not None and not vp.copy:
                merged[name] = np.concatenate(
                    [np.asarray(p) for p in parts], axis=vp.partition_dim)
            elif spec is not None and spec.partitionable and \
                    all(hasattr(p, "ndim") and getattr(p, "ndim", 0) >= 1
                        for p in parts):
                merged[name] = np.concatenate(
                    [np.asarray(p) for p in parts], axis=spec.partition_dim)
            else:
                merged[name] = parts[0]
        return merged


def _produced_names(sct: SCT) -> List[str]:
    names: List[str] = []
    for leaf in sct.leaves():
        for a in leaf.spec.outputs:
            if a.name not in names:
                names.append(a.name)
    # include function-reduction outputs of MapReduce nodes
    from repro.core.skeletons import MapReduce
    stack = [sct]
    while stack:
        n = stack.pop()
        if isinstance(n, MapReduce) and n.host_side_reduction:
            src = n.map_stage.output_names()
            if len(src) == 1:
                dst = n.out_name or f"{src[0]}_reduced"
                if dst not in names:
                    names.append(dst)
        stack.extend(n.children())
    return names


class Future:
    """Marrow's asynchronous execution handle (paper Table 1)."""

    def __init__(self, inner: cf.Future):
        self._inner = inner

    def get(self, timeout: Optional[float] = None):
        return self._inner.result(timeout)

    def done(self) -> bool:
        return self._inner.done()


class Session:
    """User-facing facade: SCT.run() -> Future over a Scheduler."""

    def __init__(self, scheduler):
        self.scheduler = scheduler
        self._pool = cf.ThreadPoolExecutor(max_workers=1)  # FCFS batch queue

    def run(self, sct: SCT, **arrays) -> Future:
        return Future(self._pool.submit(self.scheduler.run, sct, arrays))

    def shutdown(self) -> None:
        self._pool.shutdown(wait=True)
