"""Task launcher / executor — real partitioned execution on this host.

The Scheduler produces a :class:`ConcretePartitioning`; the executor turns
it into a group of tasks (one per execution slot, paper Fig. 2/3), places
them in per-slot work queues (a thread pool here), runs the SCT over each
partition, and merges the partial results:

  * partitionable outputs — concatenated along their partition dimension
    (the partitions tile the domain, paper Sec. 3.1);
  * COPY / replicated outputs — taken from the first slot;
  * reduced outputs — combined with the kernel-declared or user-supplied
    *merging function* (paper Sec. 3.4; MERGE_ADD & friends).

``Size`` / ``Offset`` traits are bound per-slot through the environment's
``__partition__`` entry.

This is the measurement backend for CPU-side experiments (fission table);
scheduling-policy experiments at device-pool scale use the calibrated
:mod:`repro.core.simulator` instead (same interface).

Failure semantics
-----------------
Execution is tracked per *segment* — a contiguous domain-unit range bound
to one slot (initially one segment per slot).  A slot that raises is
contained: its exception becomes a :class:`~repro.core.faults.FaultRecord`
instead of crashing the run, the slot is considered dead for the rest of
the request, and its segment is re-split across the surviving slots and
retried (bounded by :class:`~repro.core.faults.FaultPolicy.max_attempts`).
A per-slot watchdog deadline — ``watchdog_multiple x profile.best_time``
— declares stalled slots hung (:class:`~repro.core.faults.SlotTimeout`
semantics; note a hung *thread* cannot be killed in Python, only
abandoned).  When retries are exhausted or no slot survives, a terminal
:class:`~repro.core.faults.ExecutionError` carries the full per-slot
fault history.  Because retried segments tile the lost unit range in
domain order, merged outputs are bit-identical to the fault-free result
for concatenated outputs, and identical for associative merge functions.
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.decomposition import ConcretePartitioning
from repro.core.faults import (ExecutionError, FaultInjector, FaultPolicy,
                               FaultRecord, InjectedFault, split_units)
from repro.core.knowledge_base import Profile
from repro.core.skeletons import SCT, PartitionInfo
from repro.core.spec import ArgSpec, MergeFn, Transfer, Workload


def output_spec(sct: SCT, name: str) -> Optional[ArgSpec]:
    for leaf in sct.leaves():
        for a in leaf.spec.outputs:
            if a.name == name:
                return a
    return None


@dataclasses.dataclass
class _SlotResult:
    outputs: Dict[str, Any]
    seconds: float


@dataclasses.dataclass
class _Segment:
    """A contiguous domain-unit range assigned to one execution slot."""

    slot: int                   # index into part.slots
    start: int                  # domain-unit offset of the range
    units: int                  # domain units in the range


class ThreadedExecutor:
    """Executes SCT partitions on host threads and times each slot.

    ``injector`` (optional) deterministically injects crashes/stalls for
    fault-tolerance experiments; ``policy`` bounds the retry ladder and
    derives the watchdog deadline (see module docstring).
    """

    def __init__(self, *, merges: Optional[Dict[str, MergeFn]] = None,
                 max_workers: Optional[int] = None,
                 injector: Optional[FaultInjector] = None,
                 policy: FaultPolicy = FaultPolicy()):
        self.merges = dict(merges or {})
        self.max_workers = max_workers
        self.injector = injector
        self.policy = policy
        self._last_times: List[float] = []
        self._last_n_a: int = 0
        self.last_failures: List[FaultRecord] = []
        self.last_retries: int = 0

    # -- Scheduler interface -------------------------------------------------
    def execute(self, sct: SCT, part: ConcretePartitioning,
                arrays: Dict[str, Any], profile: Profile
                ) -> Tuple[Dict[str, Any], List[float]]:
        deadline = self.policy.deadline(getattr(profile, "best_time", None))

        segments: List[_Segment] = []
        acc = 0
        for j, units in enumerate(part.units):
            segments.append(_Segment(slot=j, start=acc, units=units))
            acc += units

        records: List[FaultRecord] = []
        retries = 0
        dead: set = set()
        done: List[Tuple[_Segment, _SlotResult]] = []
        per_slot_seconds = [0.0] * len(part.slots)

        pending = segments
        for attempt in range(self.policy.max_attempts):
            outcomes = self._run_attempt(sct, part, arrays, pending,
                                         deadline, attempt)
            failed: List[_Segment] = []
            for seg, res in zip(pending, outcomes):
                per_slot_seconds[seg.slot] += res.seconds
                if isinstance(res, FaultRecord):
                    records.append(res)
                    dead.add(seg.slot)
                    failed.append(seg)
                else:
                    done.append((seg, res))
            lost = [s for s in failed if s.units > 0]
            if not lost:
                break
            alive = [j for j in range(len(part.slots)) if j not in dead]
            if not alive:
                raise ExecutionError(
                    "partition lost: no surviving execution slot can adopt "
                    f"{sum(s.units for s in lost)} domain units",
                    records, attempt + 1)
            if attempt == self.policy.max_attempts - 1:
                raise ExecutionError(
                    f"retries exhausted after {self.policy.max_attempts} "
                    "attempts", records, attempt + 1)
            # re-split each lost range across the surviving slots, in
            # domain order, so the merged result stays bit-identical
            pending = []
            for seg in lost:
                counts = split_units(seg.units, len(alive))
                start = seg.start
                for j, u in zip(alive, counts):
                    if u:
                        pending.append(_Segment(slot=j, start=start, units=u))
                        start += u
            retries += 1

        done.sort(key=lambda sr: sr[0].start)
        outputs = self._merge(sct, part, [r.outputs for _, r in done])
        times = per_slot_seconds
        self._last_times = times
        self._last_n_a = sum(1 for s in part.slots if s.device_type != "cpu")
        self.last_failures = records
        self.last_retries = retries
        return outputs, times

    def _run_attempt(self, sct: SCT, part: ConcretePartitioning,
                     arrays: Dict[str, Any], segments: Sequence[_Segment],
                     deadline: Optional[float], attempt: int
                     ) -> List[Union[_SlotResult, FaultRecord]]:
        """Run one round of segments concurrently, containing all faults."""

        def work(seg: _Segment) -> Union[_SlotResult, FaultRecord]:
            slot = part.slots[seg.slot]
            t0 = time.perf_counter()
            try:
                if self.injector is not None:
                    kind = self.injector.decide(slot.device)
                    if kind == "crash":
                        raise InjectedFault(
                            f"injected crash on {slot.device}")
                    if kind == "stall":
                        time.sleep(self.injector.stall_seconds)
                env = self._segment_env(part, arrays, seg)
                out_env = sct.apply(env)
                for v in out_env.values():
                    if hasattr(v, "block_until_ready"):
                        v.block_until_ready()
                return _SlotResult(out_env, time.perf_counter() - t0)
            except Exception as e:       # containment: never crosses the slot
                return FaultRecord(
                    slot=seg.slot, device=slot.device,
                    device_type=slot.device_type, kind="crash",
                    attempt=attempt,
                    message=f"{type(e).__name__}: {e}",
                    seconds=time.perf_counter() - t0)

        if deadline is None and len(segments) == 1:
            return [work(segments[0])]

        nw = self.max_workers or max(len(segments), 1)
        pool = cf.ThreadPoolExecutor(max_workers=nw)
        try:
            futs = {pool.submit(work, seg): i
                    for i, seg in enumerate(segments)}
            done_f, hung = cf.wait(futs, timeout=deadline)
            outcomes: List[Union[_SlotResult, FaultRecord]] = \
                [None] * len(segments)  # type: ignore[list-item]
            for f in done_f:
                outcomes[futs[f]] = f.result()
            for f in hung:
                seg = segments[futs[f]]
                slot = part.slots[seg.slot]
                f.cancel()
                outcomes[futs[f]] = FaultRecord(
                    slot=seg.slot, device=slot.device,
                    device_type=slot.device_type, kind="timeout",
                    attempt=attempt,
                    message=f"watchdog: no completion within {deadline:.3f}s",
                    seconds=float(deadline or 0.0))
            return outcomes
        finally:
            # abandon hung threads instead of joining them (a stalled slot
            # must not block the retry round)
            pool.shutdown(wait=False, cancel_futures=True)

    def _segment_env(self, part: ConcretePartitioning, arrays: Dict[str, Any],
                     seg: _Segment) -> Dict[str, Any]:
        """Per-segment environment: slice every partitionable vector to the
        segment's unit range (each with its own epu); replicate the rest."""
        plan = part.plan
        env: Dict[str, Any] = {}
        for name, arr in arrays.items():
            vp = plan.vectors.get(name)
            if vp is None or vp.copy:
                env[name] = arr
                continue
            off = seg.start * vp.epu
            size = seg.units * vp.epu
            idx = [slice(None)] * arr.ndim
            idx[vp.partition_dim] = slice(off, off + size)
            env[name] = arr[tuple(idx)]
        witness = next((v for v in plan.vectors.values() if not v.copy), None)
        if witness is not None:
            env["__partition__"] = PartitionInfo(
                size=seg.units * witness.epu,
                offset=seg.start * witness.epu)
        return env

    def last_class_times(self) -> Tuple[float, float]:
        n_a = self._last_n_a
        t = self._last_times
        ta = max(t[:n_a]) if n_a else 0.0
        tb = max(t[n_a:]) if len(t) > n_a else 0.0
        return ta, tb

    def synthesise_arrays(self, sct: SCT, workload: Workload
                          ) -> Dict[str, Any]:
        """Random arrays matching a workload (Algorithm 1 evaluations)."""
        rng = np.random.default_rng(0)
        out: Dict[str, Any] = {}
        for a in sct.free_inputs():
            if a.kind == "scalar":
                out[a.name] = np.float32(1.0)
            else:
                out[a.name] = rng.standard_normal(workload.dims
                                                  ).astype(np.float32)
        return out

    # -- merging ---------------------------------------------------------------
    def _merge(self, sct: SCT, part: ConcretePartitioning,
               envs: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
        merged: Dict[str, Any] = {}
        for name in _produced_names(sct):
            parts = [e[name] for e in envs if name in e]
            if not parts:
                continue
            if name in self.merges:
                merged[name] = self.merges[name](parts)
                continue
            spec = output_spec(sct, name)
            vp = part.plan.vectors.get(name)
            if vp is not None and not vp.copy:
                merged[name] = np.concatenate(
                    [np.asarray(p) for p in parts], axis=vp.partition_dim)
            elif spec is not None and spec.partitionable and \
                    all(hasattr(p, "ndim") and getattr(p, "ndim", 0) >= 1
                        for p in parts):
                merged[name] = np.concatenate(
                    [np.asarray(p) for p in parts], axis=spec.partition_dim)
            else:
                merged[name] = parts[0]
        return merged


def _produced_names(sct: SCT) -> List[str]:
    names: List[str] = []
    for leaf in sct.leaves():
        for a in leaf.spec.outputs:
            if a.name not in names:
                names.append(a.name)
    # include function-reduction outputs of MapReduce nodes
    from repro.core.skeletons import MapReduce
    stack = [sct]
    while stack:
        n = stack.pop()
        if isinstance(n, MapReduce) and n.host_side_reduction:
            src = n.map_stage.output_names()
            if len(src) == 1:
                dst = n.out_name or f"{src[0]}_reduced"
                if dst not in names:
                    names.append(dst)
        stack.extend(n.children())
    return names


class Future:
    """Marrow's asynchronous execution handle (paper Table 1).

    ``get`` re-raises executor failures as
    :class:`~repro.core.faults.ExecutionError` with the failing slot /
    device identity attached, instead of a bare pool exception.
    """

    def __init__(self, inner: cf.Future, deadline: Optional[float] = None):
        self._inner = inner
        self._deadline = deadline

    def get(self, timeout: Optional[float] = None):
        timeout = timeout if timeout is not None else self._deadline
        try:
            return self._inner.result(timeout)
        except ExecutionError:
            raise
        except cf.TimeoutError:
            raise ExecutionError(
                f"request did not complete within {timeout}s") from None
        except Exception as e:
            raise ExecutionError(
                f"execution failed: {type(e).__name__}: {e}",
                getattr(e, "records", [])) from e

    def done(self) -> bool:
        return self._inner.done()


class Session:
    """User-facing facade: SCT.run() -> Future over a Scheduler.

    Usable as a context manager (``with Session(sched) as s: ...`` shuts
    the request queue down on exit).  ``run`` accepts a request-level
    ``deadline`` (seconds, enforced across retries and by ``Future.get``)
    and ``retries`` with exponential backoff on terminal
    :class:`~repro.core.faults.ExecutionError`.
    """

    def __init__(self, scheduler):
        self.scheduler = scheduler
        self._pool = cf.ThreadPoolExecutor(max_workers=1)  # FCFS batch queue

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown()

    def run(self, sct: SCT, *, deadline: Optional[float] = None,
            retries: int = 0, retry_backoff: float = 0.05,
            **arrays) -> Future:
        def attempt_loop():
            t0 = time.monotonic()
            last: Optional[ExecutionError] = None
            for k in range(retries + 1):
                if deadline is not None and time.monotonic() - t0 > deadline:
                    raise ExecutionError(
                        f"request deadline {deadline}s exceeded after "
                        f"{k} attempts",
                        getattr(last, "records", []), k)
                try:
                    return self.scheduler.run(sct, arrays)
                except ExecutionError as e:
                    last = e
                    if k == retries:
                        raise
                    time.sleep(retry_backoff * (2 ** k))
            raise last  # pragma: no cover — loop always returns or raises

        return Future(self._pool.submit(attempt_loop), deadline=deadline)

    def shutdown(self) -> None:
        self._pool.shutdown(wait=True)
