"""Task launcher / executor — real partitioned execution on this host.

The Scheduler produces a :class:`ConcretePartitioning`; the executor turns
it into a group of tasks (one per execution slot, paper Fig. 2/3), places
them in per-slot work queues (a persistent thread pool here), runs the SCT
over each partition, and merges the partial results:

  * partitionable outputs — assembled along their partition dimension
    (the partitions tile the domain, paper Sec. 3.1);
  * COPY / replicated outputs — taken from the first slot;
  * reduced outputs — combined with the kernel-declared or user-supplied
    *merging function* (paper Sec. 3.4; MERGE_ADD & friends).

``Size`` / ``Offset`` traits are bound per-slot through the environment's
``__partition__`` entry.

Locality / zero-copy pipeline
-----------------------------
Recurrent runs of the same (SCT, workload) are the serving-loop regime the
paper's data-locality results target, so the hot path amortises every
per-dispatch cost:

  * **persistent worker pool** — created once, reused across runs and
    retry attempts, torn down by :meth:`ThreadedExecutor.close` (called
    from ``Session.shutdown``).  The pool is only re-created after a
    watchdog timeout, since a hung thread can never be reclaimed.
  * **zero-copy segment environments** — per-slot input slices are numpy
    views into the caller's arrays, never copies.
  * **in-place merge** — partitionable outputs are written by each slot
    directly into a preallocated, shape-keyed output buffer that is
    reused across runs; the merge phase then copies zero bytes.  The
    first run of a new output shape falls back to one packing copy while
    the buffer is learned.  *Consequence*: the arrays returned by one
    ``execute`` are overwritten by the next run on the same executor —
    callers that retain outputs across runs must copy them (or construct
    the executor with ``reuse_buffers=False``).
  * **partitioned residency** — ``execute(..., keep_resident=True)``
    skips the merge entirely and hands back a :class:`ResidentPartition`
    whose slot-local outputs feed the next SCT's slot-local inputs
    (``execute(..., resident=...)``), eliminating the merge→re-split
    round trip between the kernels of a compound chain (the paper's
    inter-kernel locality rule).  Whenever the next run's partitioning
    differs — other slots/shares, other partition dims or epu, or a
    fault-repartitioned layout — the handle transparently *materialises*
    (full merge) and the run proceeds on the safe path.

Merge precedence (per output name): 1. a user-supplied merge function in
``ThreadedExecutor.merges`` — honoured even when the output is also
partitionable; 2. in-place assembly along the partition dim for
partitionable outputs; 3. first slot's value for COPY / scalar outputs.
Direct slot writes assume deterministic kernels (a timed-out slot retried
elsewhere re-produces the same bytes); merged results are bit-identical
to the historical ``np.concatenate`` merge.

Failure semantics
-----------------
Execution is tracked per *segment* — a contiguous domain-unit range bound
to one slot (initially one segment per slot).  A slot that raises is
contained: its exception becomes a :class:`~repro.core.faults.FaultRecord`
instead of crashing the run, the slot is considered dead for the rest of
the request, and its segment is re-split across the surviving slots and
retried (bounded by :class:`~repro.core.faults.FaultPolicy.max_attempts`).
A per-slot watchdog deadline — ``watchdog_multiple x profile.best_time``
— declares stalled slots hung (:class:`~repro.core.faults.SlotTimeout`
semantics; note a hung *thread* cannot be killed in Python, only
abandoned — the persistent pool and the output buffers are retired after
a timeout so an abandoned thread can never touch a later run's state).
When retries are exhausted or no slot survives, a terminal
:class:`~repro.core.faults.ExecutionError` carries the full per-slot
fault history.  Because retried segments tile the lost unit range in
domain order, merged outputs are bit-identical to the fault-free result
for concatenated outputs, and identical for associative merge functions.
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.decomposition import ConcretePartitioning
from repro.core.faults import (ExecutionError, FaultInjector, FaultPolicy,
                               FaultRecord, InjectedFault, split_units)
from repro.core.graph import GraphHandle, GraphResult, JobGraph
from repro.core.knowledge_base import Profile
from repro.core.skeletons import SCT, PartitionInfo
from repro.core.spec import ArgSpec, MergeFn, Transfer, Workload
from repro.core.telemetry import NULL_TELEMETRY, Telemetry


def output_spec(sct: SCT, name: str) -> Optional[ArgSpec]:
    for leaf in sct.leaves():
        for a in leaf.spec.outputs:
            if a.name == name:
                return a
    return None


@dataclasses.dataclass
class ExecResult:
    """Everything one ``execute`` call produced, as a per-call value.

    Concurrent graph nodes share one executor, so per-call results must
    travel with the call instead of through mutable ``last_*`` fields
    (which remain, updated by :meth:`ThreadedExecutor.execute`, for
    sequential callers and older integrations).
    """

    outputs: Dict[str, Any]
    times: List[float]                      # per-slot busy seconds
    failures: List[FaultRecord]
    retries: int
    timing: Dict[str, float]                # pool/compute/merge/dispatch
    merge_bytes: int
    direct_bytes: int
    resident: Optional["ResidentPartition"]
    n_a: int                                # accelerator-class slot count


@dataclasses.dataclass
class _SlotResult:
    outputs: Dict[str, Any]
    seconds: float
    written: frozenset = frozenset()    # outputs direct-written to buffers


@dataclasses.dataclass
class _Segment:
    """A contiguous domain-unit range assigned to one execution slot."""

    slot: int                   # index into part.slots
    start: int                  # domain-unit offset of the range
    units: int                  # domain units in the range


@dataclasses.dataclass
class _OutputTarget:
    """Preallocated destination for one partitionable output."""

    buffer: np.ndarray
    axis: int
    epu: int


@dataclasses.dataclass
class ResidentPartition:
    """Slot-resident outputs of one SCT run over a concrete partitioning.

    Holds one environment per realised segment, restricted to produced
    (and inherited) vector names, so a back-to-back run over the *same*
    domain decomposition can consume them slot-locally without the
    merge→re-split round trip.  ``meta`` records each resident vector's
    ``(partition_dim, epu)``; ``extras`` carries non-partitionable
    results (reduced / COPY / user-merged outputs and values carried
    forward from earlier chain steps) as whole arrays.

    ``compatible`` gates the zero-copy handoff; on any mismatch the
    consumer calls :meth:`materialize` and falls back to the full-merge
    path, so chaining is never less correct than merging.
    """

    part: ConcretePartitioning
    layout: Tuple[Tuple[int, int], ...]     # realised (start, units) ranges
    envs: List[Dict[str, Any]]              # slot-local arrays per segment
    meta: Dict[str, Tuple[int, int]]        # name -> (axis, epu)
    extras: Dict[str, Any]                  # whole-array results
    executor: "ThreadedExecutor"
    sct: SCT

    def __post_init__(self) -> None:
        self._index = {rng: i for i, rng in enumerate(self.layout)}

    # -- zero-copy handoff --------------------------------------------------
    def compatible(self, part: ConcretePartitioning) -> bool:
        """True when ``part`` can consume the resident data slot-locally."""
        if not self.part.same_layout(part):
            return False
        if self.layout != part.layout():
            return False                    # fault-repartitioned realisation
        for name, (axis, epu) in self.meta.items():
            vp = part.plan.vectors.get(name)
            if vp is None:
                continue                    # next SCT does not touch it
            if vp.copy or vp.partition_dim != axis or vp.epu != epu:
                return False
        return True

    def segment_env(self, start: int, units: int) -> Dict[str, Any]:
        """Slot-local resident values covering one segment range.

        Exact layout matches return the stored environment; sub-ranges —
        the fault path re-splits a lost segment across survivors — are
        served as views into the covering segment's arrays, so retries
        stay zero-copy and bit-identical."""
        i = self._index.get((start, units))
        if i is not None:
            return self.envs[i]
        for (s0, u0), j in self._index.items():
            if s0 <= start and start + units <= s0 + u0:
                out: Dict[str, Any] = {}
                for name, v in self.envs[j].items():
                    axis, epu = self.meta[name]
                    off = (start - s0) * epu
                    idx = [slice(None)] * v.ndim
                    idx[axis] = slice(off, off + units * epu)
                    out[name] = v[tuple(idx)]
                return out
        return {}

    # -- introspection ------------------------------------------------------
    def names(self) -> List[str]:
        seen = dict.fromkeys(self.meta)
        seen.update(dict.fromkeys(self.extras))
        return list(seen)

    def shapes(self) -> Dict[str, Tuple[int, ...]]:
        """Global (merged) shapes of every resident vector."""
        out: Dict[str, Tuple[int, ...]] = {}
        for name, (axis, _) in self.meta.items():
            parts = [e[name] for e in self.envs if name in e]
            if not parts:
                continue
            shape = list(np.shape(parts[0]))
            shape[axis] = sum(int(np.shape(p)[axis]) for p in parts)
            out[name] = tuple(shape)
        for name, v in self.extras.items():
            if hasattr(v, "shape"):
                out[name] = tuple(v.shape)
        return out

    # -- safe fallback ------------------------------------------------------
    def materialize(self) -> Dict[str, Any]:
        """Full merge of the resident outputs (the safe fallback)."""
        merged, _ = self.materialize_counted()
        return merged

    def materialize_counted(self) -> Tuple[Dict[str, Any], int]:
        # assemble along each vector's own recorded axis (never via the
        # current SCT's specs — carried vectors may not appear in them)
        merged: Dict[str, Any] = {}
        nbytes = 0
        for name, (axis, _) in self.meta.items():
            parts = [e[name] for e in self.envs if name in e]
            if not parts:
                continue
            out = np.concatenate(
                [p if isinstance(p, np.ndarray) else np.asarray(p)
                 for p in parts], axis=axis)
            merged[name] = out
            nbytes += out.nbytes
        merged.update(self.extras)
        return merged, nbytes


class ThreadedExecutor:
    """Executes SCT partitions on host threads and times each slot.

    ``injector`` (optional) deterministically injects crashes/stalls for
    fault-tolerance experiments; ``policy`` bounds the retry ladder and
    derives the watchdog deadline (see module docstring).

    ``persistent_pool`` / ``inplace_merge`` / ``reuse_buffers`` gate the
    locality optimisations; all default on.  Disabling them restores the
    historical per-attempt pool and ``np.concatenate`` merge — useful as
    the baseline leg of ``benchmarks/locality.py`` and for callers that
    must retain outputs across runs without copying.
    """

    supports_residency = True

    def __init__(self, *, merges: Optional[Dict[str, MergeFn]] = None,
                 max_workers: Optional[int] = None,
                 injector: Optional[FaultInjector] = None,
                 policy: FaultPolicy = FaultPolicy(),
                 persistent_pool: bool = True,
                 inplace_merge: bool = True,
                 reuse_buffers: bool = True,
                 telemetry: Optional[Telemetry] = None):
        self.telemetry = telemetry or NULL_TELEMETRY
        self.merges = dict(merges or {})
        self.max_workers = max_workers
        self.injector = injector
        self.policy = policy
        self.persistent_pool = persistent_pool
        self.inplace_merge = inplace_merge
        self.reuse_buffers = reuse_buffers
        self._last_times: List[float] = []
        self._last_n_a: int = 0
        self.last_failures: List[FaultRecord] = []
        self.last_retries: int = 0
        self.last_timing: Dict[str, float] = {}
        self.last_merge_bytes: int = 0
        self.last_direct_bytes: int = 0
        self.last_resident: Optional[ResidentPartition] = None
        self.pools_created: int = 0
        self.pool_reuses: int = 0
        self._pool: Optional[cf.ThreadPoolExecutor] = None
        self._pool_size: int = 0
        self._queues: Dict[str, cf.ThreadPoolExecutor] = {}
        self._queue_lock = threading.Lock()
        self._buf_lock = threading.Lock()
        self._inuse: set = set()            # id() of buffers leased to a run
        self._buffers: Dict[Tuple[str, Tuple[int, ...], str], np.ndarray] = {}
        self._out_shapes: Dict[Tuple[str, str],
                               Tuple[Tuple[int, ...], np.dtype]] = {}

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Tear down pools / work queues and drop reusable buffers.

        Idempotent: a second ``close`` (double ``Session.shutdown``, a
        context-manager exit after an explicit shutdown) is a no-op."""
        self._retire_pool()
        self._retire_queues()
        with self._buf_lock:
            self._buffers = {}
            self._inuse = set()
        self._out_shapes = {}

    def _retire_pool(self) -> None:
        if self._pool is not None:
            # abandon hung threads instead of joining them (a stalled slot
            # must not block shutdown or the retry round)
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None
            self._pool_size = 0

    def _retire_queues(self, devices: Optional[Sequence[str]] = None) -> None:
        """Retire all per-device work queues, or just the given devices
        (a hung slot taints only its own device's queue)."""
        with self._queue_lock:
            names = list(self._queues) if devices is None \
                else [d for d in devices if d in self._queues]
            doomed = [self._queues.pop(d) for d in names]
        for q in doomed:
            q.shutdown(wait=False, cancel_futures=True)

    def _acquire_pool(self, n: int) -> cf.ThreadPoolExecutor:
        with self.telemetry.tracer.span("pool", workers=n) as sp:
            if self._pool is not None and self._pool_size < n:
                self._retire_pool()
            if self._pool is None:
                self._pool = cf.ThreadPoolExecutor(max_workers=n)
                self._pool_size = n
                self.pools_created += 1
                self.telemetry.metrics.counter("pools_created_total").inc()
                sp.note(created=True)
            else:
                self.pool_reuses += 1
                self.telemetry.metrics.counter("pool_reuses_total").inc()
        return self._pool

    def _acquire_queues(self, devices: Sequence[str]
                        ) -> Dict[str, cf.ThreadPoolExecutor]:
        """Per-device work queues (paper Fig. 2): one single-worker pool
        per execution-slot device, shared by every concurrent run.  Two
        segments bound to the same device serialise in its queue;
        segments on disjoint devices genuinely overlap — including
        segments of *different* graph nodes."""
        with self.telemetry.tracer.span("pool", workers=len(devices)) as sp:
            created = False
            with self._queue_lock:
                for d in devices:
                    if d not in self._queues:
                        self._queues[d] = cf.ThreadPoolExecutor(
                            max_workers=1,
                            thread_name_prefix=f"wq-{d.replace('/', '-')}")
                        created = True
                qmap = {d: self._queues[d] for d in devices}
            if created:
                self.pools_created += 1
                self.telemetry.metrics.counter("pools_created_total").inc()
                sp.note(created=True)
            else:
                self.pool_reuses += 1
                self.telemetry.metrics.counter("pool_reuses_total").inc()
        return qmap

    # -- Scheduler interface -------------------------------------------------
    def execute(self, sct: SCT, part: ConcretePartitioning,
                arrays: Dict[str, Any], profile: Profile, *,
                resident: Optional[ResidentPartition] = None,
                keep_resident: bool = False
                ) -> Tuple[Dict[str, Any], List[float]]:
        """Sequential-caller facade: runs and publishes the ``last_*``
        observation fields (not safe under concurrent callers — those go
        through :meth:`execute_result`)."""
        res = self.execute_result(sct, part, arrays, profile,
                                  resident=resident,
                                  keep_resident=keep_resident)
        self._last_times = res.times
        self._last_n_a = res.n_a
        self.last_failures = res.failures
        self.last_retries = res.retries
        self.last_timing = res.timing
        self.last_merge_bytes = res.merge_bytes
        self.last_direct_bytes = res.direct_bytes
        self.last_resident = res.resident
        return res.outputs, res.times

    def execute_result(self, sct: SCT, part: ConcretePartitioning,
                       arrays: Dict[str, Any], profile: Profile, *,
                       resident: Optional[ResidentPartition] = None,
                       keep_resident: bool = False) -> ExecResult:
        """Execute one partitioned run and return a per-call result.

        Thread-safe: concurrent graph nodes share the per-device work
        queues and the buffer pool (leased per call), and nothing about
        this call is observed through shared mutable state."""
        with self.telemetry.tracer.span(
                "dispatch", sct=sct.unique_id(), slots=len(part.slots),
                keep_resident=keep_resident) as sp:
            res = self._execute(
                sct, part, arrays, profile, resident=resident,
                keep_resident=keep_resident)
            sp.note(retries=res.retries,
                    merge_bytes=res.merge_bytes,
                    resident=res.resident is not None)
            return res

    def _execute(self, sct: SCT, part: ConcretePartitioning,
                 arrays: Dict[str, Any], profile: Profile, *,
                 resident: Optional[ResidentPartition] = None,
                 keep_resident: bool = False) -> ExecResult:
        leases: List[np.ndarray] = []   # buffers leased to this call
        try:
            return self._execute_leased(sct, part, arrays, profile, leases,
                                        resident=resident,
                                        keep_resident=keep_resident)
        finally:
            # end of the run releases its buffer leases: the *next* run may
            # overwrite the returned arrays (the documented aliasing
            # contract), but a *concurrent* run never shares them
            if leases:
                with self._buf_lock:
                    for b in leases:
                        self._inuse.discard(id(b))

    def _execute_leased(self, sct: SCT, part: ConcretePartitioning,
                        arrays: Dict[str, Any], profile: Profile,
                        leases: List[np.ndarray], *,
                        resident: Optional[ResidentPartition] = None,
                        keep_resident: bool = False) -> ExecResult:
        t_run0 = time.perf_counter()
        pool_sec = [0.0]                # mutable: charged by _run_attempt
        merge_bytes = 0
        deadline = self.policy.deadline(getattr(profile, "best_time", None))

        inherited_extras: Dict[str, Any] = {}
        if resident is not None:
            if resident.compatible(part):
                inherited_extras.update(resident.extras)
            else:
                # safe fallback: partition dims / shares / layout differ
                materialized, nbytes = resident.materialize_counted()
                merge_bytes += nbytes
                inherited_extras.update(materialized)
                arrays = {**arrays, **materialized}
                resident = None

        segments = [_Segment(slot=j, start=s, units=u)
                    for j, (s, u) in enumerate(part.layout())]

        targets: Dict[str, _OutputTarget] = {}
        if self.inplace_merge and not keep_resident:
            targets = self._output_targets(sct, part, leases)

        records: List[FaultRecord] = []
        retries = 0
        dead: set = set()
        done: List[Tuple[_Segment, _SlotResult]] = []
        per_slot_seconds = [0.0] * len(part.slots)

        tel = self.telemetry
        attempts_seconds = 0.0
        pending = segments
        for attempt in range(self.policy.max_attempts):
            t_a0 = time.perf_counter()
            with tel.tracer.span("attempt", attempt=attempt,
                                 segments=len(pending)) as att_span:
                outcomes = self._run_attempt(sct, part, arrays, pending,
                                             deadline, attempt, resident,
                                             targets, pool_sec)
                attempts_seconds += time.perf_counter() - t_a0
                failed: List[_Segment] = []
                for seg, res in zip(pending, outcomes):
                    per_slot_seconds[seg.slot] += res.seconds
                    if isinstance(res, FaultRecord):
                        records.append(res)
                        dead.add(seg.slot)
                        failed.append(seg)
                        tel.metrics.counter("faults_total",
                                            kind=res.kind).inc()
                        tel.events.emit(
                            "fault", level="warning", message=res.message,
                            device=res.device, fault_kind=res.kind,
                            attempt=res.attempt, slot=res.slot)
                    else:
                        done.append((seg, res))
                att_span.note(faults=len(failed))
            lost = [s for s in failed if s.units > 0]
            if not lost:
                break
            alive = [j for j in range(len(part.slots)) if j not in dead]
            if not alive:
                raise ExecutionError(
                    "partition lost: no surviving execution slot can adopt "
                    f"{sum(s.units for s in lost)} domain units",
                    records, attempt + 1)
            if attempt == self.policy.max_attempts - 1:
                raise ExecutionError(
                    f"retries exhausted after {self.policy.max_attempts} "
                    "attempts", records, attempt + 1)
            # re-split each lost range across the surviving slots, in
            # domain order, so the merged result stays bit-identical
            pending = []
            for seg in lost:
                counts = split_units(seg.units, len(alive))
                start = seg.start
                for j, u in zip(alive, counts):
                    if u:
                        pending.append(_Segment(slot=j, start=start, units=u))
                        start += u
            retries += 1
            tel.events.emit("retry.repartition",
                            lost_units=sum(s.units for s in lost),
                            survivors=len(alive), attempt=attempt)

        if any(r.kind == "timeout" for r in records):
            # an abandoned hung thread may still write into the current
            # buffers — retire them so later runs get untainted memory
            with self._buf_lock:
                self._buffers = {}
            tel.events.emit("buffers.dropped", level="warning",
                            message="output buffers retired after a slot "
                                    "timeout (hung-thread containment)")

        done.sort(key=lambda sr: sr[0].start)
        clean = retries == 0 and not records
        t_m0 = time.perf_counter()
        resident_out: Optional[ResidentPartition] = None
        direct_bytes = 0
        if keep_resident and clean:
            with tel.tracer.span("resident-handoff", segments=len(done)):
                resident_out = self._make_resident(
                    sct, part, done, resident, inherited_extras)
            outputs: Dict[str, Any] = {}
        else:
            with tel.tracer.span("merge") as merge_span:
                outputs, copied, direct_bytes = self._merge(
                    sct, part, done, targets, leases)
                merge_span.note(merge_bytes=copied)
            merge_bytes += copied
            if inherited_extras and keep_resident:
                # chain fallback: surface carried values with the merge
                outputs = {**inherited_extras, **outputs}
        merge_seconds = time.perf_counter() - t_m0

        times = per_slot_seconds
        total = time.perf_counter() - t_run0
        compute = max(attempts_seconds - pool_sec[0], 0.0)
        timing = {
            "pool": pool_sec[0],
            "compute": compute,
            "merge": merge_seconds,
            "dispatch": max(total - attempts_seconds - merge_seconds, 0.0),
        }
        return ExecResult(
            outputs=outputs, times=times, failures=records, retries=retries,
            timing=timing, merge_bytes=merge_bytes,
            direct_bytes=direct_bytes, resident=resident_out,
            n_a=sum(1 for s in part.slots if s.device_type != "cpu"))

    def _run_attempt(self, sct: SCT, part: ConcretePartitioning,
                     arrays: Dict[str, Any], segments: Sequence[_Segment],
                     deadline: Optional[float], attempt: int,
                     resident: Optional[ResidentPartition] = None,
                     targets: Optional[Dict[str, _OutputTarget]] = None,
                     pool_sec: Optional[List[float]] = None
                     ) -> List[Union[_SlotResult, FaultRecord]]:
        """Run one round of segments concurrently, containing all faults."""
        targets = targets or {}
        pool_sec = pool_sec if pool_sec is not None else [0.0]

        def work(seg: _Segment) -> Union[_SlotResult, FaultRecord]:
            slot = part.slots[seg.slot]
            t0 = time.perf_counter()
            with self.telemetry.tracer.span(
                    "slot", device=slot.device, units=seg.units,
                    offset=seg.start, attempt=attempt) as sp:
                try:
                    if self.injector is not None:
                        kind = self.injector.decide(slot.device)
                        if kind == "crash":
                            raise InjectedFault(
                                f"injected crash on {slot.device}")
                        if kind == "stall":
                            time.sleep(self.injector.stall_seconds)
                    env = self._segment_env(part, arrays, seg, resident)
                    out_env = sct.apply(env)
                    for v in out_env.values():
                        if hasattr(v, "block_until_ready"):
                            v.block_until_ready()
                    written = self._direct_write(out_env, seg, targets)
                    return _SlotResult(out_env, time.perf_counter() - t0,
                                       written)
                except Exception as e:   # containment: never crosses the slot
                    sp.note(fault=type(e).__name__)
                    return FaultRecord(
                        slot=seg.slot, device=slot.device,
                        device_type=slot.device_type, kind="crash",
                        attempt=attempt,
                        message=f"{type(e).__name__}: {e}",
                        seconds=time.perf_counter() - t0)

        if deadline is None and len(segments) == 1:
            return [work(segments[0])]

        # three dispatch modes: per-device work queues (default), one
        # shared persistent pool (explicit max_workers), per-run pool
        # (persistent_pool=False, the historical baseline)
        use_queues = self.persistent_pool and self.max_workers is None
        t0 = time.perf_counter()
        pool: Optional[cf.ThreadPoolExecutor] = None
        if use_queues:
            qmap = self._acquire_queues(
                list(dict.fromkeys(part.slots[seg.slot].device
                                   for seg in segments)))
        elif self.persistent_pool:
            pool = self._acquire_pool(self.max_workers)
        else:
            pool = cf.ThreadPoolExecutor(
                max_workers=self.max_workers or max(len(segments), 1))
        pool_sec[0] += time.perf_counter() - t0
        hung: set = set()
        try:
            if use_queues:
                futs = {qmap[part.slots[seg.slot].device].submit(work, seg): i
                        for i, seg in enumerate(segments)}
            else:
                futs = {pool.submit(work, seg): i
                        for i, seg in enumerate(segments)}
            done_f, hung = cf.wait(futs, timeout=deadline)
            outcomes: List[Union[_SlotResult, FaultRecord]] = \
                [None] * len(segments)  # type: ignore[list-item]
            for f in done_f:
                outcomes[futs[f]] = f.result()
            for f in hung:
                seg = segments[futs[f]]
                slot = part.slots[seg.slot]
                f.cancel()
                outcomes[futs[f]] = FaultRecord(
                    slot=seg.slot, device=slot.device,
                    device_type=slot.device_type, kind="timeout",
                    attempt=attempt,
                    message=f"watchdog: no completion within {deadline:.3f}s",
                    seconds=float(deadline or 0.0))
            return outcomes
        finally:
            # abandon hung threads instead of joining them (a stalled
            # slot must not block the retry round); a tainted persistent
            # pool / device queue is recreated on next acquisition
            if use_queues:
                if hung:
                    self._retire_queues(
                        {part.slots[segments[futs[f]].slot].device
                         for f in hung})
            elif not self.persistent_pool:
                pool.shutdown(wait=False, cancel_futures=True)
            elif hung:
                self._retire_pool()

    def _segment_env(self, part: ConcretePartitioning, arrays: Dict[str, Any],
                     seg: _Segment,
                     resident: Optional[ResidentPartition] = None
                     ) -> Dict[str, Any]:
        """Per-segment environment: slice every partitionable vector to the
        segment's unit range (each slice a zero-copy view, with its own
        epu); replicate the rest.  Resident slot-local values, when
        given, shadow both and skip the slicing entirely."""
        plan = part.plan
        env: Dict[str, Any] = {}
        res_env: Optional[Dict[str, Any]] = None
        source = arrays
        if resident is not None:
            res_env = resident.segment_env(seg.start, seg.units)
            if resident.extras:
                source = {**arrays, **resident.extras}
        for name, arr in source.items():
            if res_env is not None and name in res_env:
                continue
            vp = plan.vectors.get(name)
            if vp is None or vp.copy:
                env[name] = arr
                continue
            off = seg.start * vp.epu
            size = seg.units * vp.epu
            idx = [slice(None)] * arr.ndim
            idx[vp.partition_dim] = slice(off, off + size)
            env[name] = arr[tuple(idx)]     # view, not a copy
        if res_env:
            env.update(res_env)
        witness = next((v for v in plan.vectors.values() if not v.copy), None)
        if witness is not None:
            env["__partition__"] = PartitionInfo(
                size=seg.units * witness.epu,
                offset=seg.start * witness.epu)
        return env

    def last_class_times(self) -> Tuple[float, float]:
        n_a = self._last_n_a
        t = self._last_times
        ta = max(t[:n_a]) if n_a else 0.0
        tb = max(t[n_a:]) if len(t) > n_a else 0.0
        return ta, tb

    def synthesise_arrays(self, sct: SCT, workload: Workload
                          ) -> Dict[str, Any]:
        """Random arrays matching a workload (Algorithm 1 evaluations)."""
        rng = np.random.default_rng(0)
        out: Dict[str, Any] = {}
        for a in sct.free_inputs():
            if a.kind == "scalar":
                out[a.name] = np.float32(1.0)
            else:
                out[a.name] = rng.standard_normal(workload.dims
                                                  ).astype(np.float32)
        return out

    # -- output buffers / direct slot writes ----------------------------------
    def _axis_epu(self, sct: SCT, part: ConcretePartitioning,
                  name: str) -> Optional[Tuple[int, int]]:
        """(partition_dim, epu) of a partitionable output, else None."""
        vp = part.plan.vectors.get(name)
        if vp is not None:
            return None if vp.copy else (vp.partition_dim, vp.epu)
        spec = output_spec(sct, name)
        if spec is not None and spec.partitionable:
            return (spec.partition_dim, spec.epu)
        return None

    def _get_buffer(self, name: str, shape: Tuple[int, ...],
                    dtype: np.dtype, leases: List[np.ndarray]) -> np.ndarray:
        """Lease a reusable output buffer to the calling run.

        A buffer leased to a still-running concurrent call is never
        handed out again; the requester gets a fresh allocation instead
        (stored as the new cached buffer).  Leases are released at the
        end of ``_execute`` — preserving the sequential aliasing
        contract (the next run may overwrite returned arrays) while
        overlapping runs stay isolated."""
        key = (name, tuple(shape), np.dtype(dtype).str)
        with self._buf_lock:
            buf = self._buffers.get(key)
            if buf is not None and id(buf) in self._inuse:
                buf = None              # leased to a concurrent run
            if buf is None:
                buf = np.empty(shape, dtype)
                if self.reuse_buffers:
                    self._buffers[key] = buf
            if self.reuse_buffers:
                self._inuse.add(id(buf))
                leases.append(buf)
        return buf

    def _output_targets(self, sct: SCT, part: ConcretePartitioning,
                        leases: List[np.ndarray]
                        ) -> Dict[str, _OutputTarget]:
        """Preallocated destinations for outputs whose shape is known.

        Shapes are learned from the first run of each (SCT, output); from
        then on slots write their partition directly into the shared
        buffer and the merge phase copies zero bytes."""
        targets: Dict[str, _OutputTarget] = {}
        sid = sct.unique_id()
        for name in _produced_names(sct):
            if name in self.merges:
                continue        # user merge fn takes precedence: no buffer
            ae = self._axis_epu(sct, part, name)
            if ae is None:
                continue
            axis, epu = ae
            known = self._out_shapes.get((sid, name))
            if known is None:
                continue
            shape, dtype = known
            if axis >= len(shape) or \
                    shape[axis] != part.plan.domain_units * epu:
                continue        # workload changed: re-learn on this run
            targets[name] = _OutputTarget(
                buffer=self._get_buffer(name, shape, dtype, leases),
                axis=axis, epu=epu)
        return targets

    def _direct_write(self, out_env: Dict[str, Any], seg: _Segment,
                      targets: Dict[str, _OutputTarget]) -> frozenset:
        """Write this segment's partitionable outputs straight into the
        preallocated buffers (zero-copy merge); returns the names written."""
        if not targets:
            return frozenset()
        written = set()
        for name, tg in targets.items():
            v = out_env.get(name)
            if v is None or getattr(v, "ndim", 0) < 1:
                continue
            expect = seg.units * tg.epu
            if np.shape(v)[tg.axis] != expect:
                continue        # kernel reshaped the output: merge-path copy
            idx = [slice(None)] * tg.buffer.ndim
            off = seg.start * tg.epu
            idx[tg.axis] = slice(off, off + expect)
            dst = tg.buffer[tuple(idx)]
            if np.shape(v) != dst.shape:
                continue
            dst[...] = v        # single device→buffer conversion + copy
            written.add(name)
        return frozenset(written)

    # -- merging ---------------------------------------------------------------
    def _merge(self, sct: SCT, part: ConcretePartitioning,
               done: Sequence[Tuple[_Segment, _SlotResult]],
               targets: Optional[Dict[str, _OutputTarget]] = None,
               leases: Optional[List[np.ndarray]] = None
               ) -> Tuple[Dict[str, Any], int, int]:
        """Merge per-segment outputs; returns
        (outputs, bytes copied, bytes direct-written).

        Precedence per output name (documented contract):
          1. a user-supplied merge function (``self.merges``) — honoured
             even when the output is also partitionable;
          2. in-place assembly along the partition dim (or, with
             ``inplace_merge=False``, the historical ``np.concatenate``)
             for partitionable array outputs;
          3. the first slot's value (COPY / replicated / scalar outputs).
        """
        targets = targets or {}
        leases = leases if leases is not None else []
        merged: Dict[str, Any] = {}
        bytes_copied = 0
        direct_bytes = 0
        sid = sct.unique_id()
        for name in _produced_names(sct):
            pieces = [(seg, res) for seg, res in done if name in res.outputs]
            if not pieces:
                continue
            parts = [res.outputs[name] for _, res in pieces]
            if name in self.merges:
                merged[name] = self.merges[name](parts)
                continue
            ae = self._axis_epu(sct, part, name)
            if ae is None or not all(getattr(p, "ndim", 0) >= 1
                                     for p in parts):
                merged[name] = parts[0]
                continue
            axis, _ = ae
            if not self.inplace_merge:
                merged[name] = np.concatenate(
                    [p if isinstance(p, np.ndarray) else np.asarray(p)
                     for p in parts], axis=axis)
                bytes_copied += merged[name].nbytes
                continue
            out, copied, direct = self._assemble(
                name, axis, pieces, targets.get(name), leases)
            merged[name] = out
            bytes_copied += copied
            direct_bytes += direct
            self._out_shapes[(sid, name)] = (tuple(out.shape), out.dtype)
        return merged, bytes_copied, direct_bytes

    def _assemble(self, name: str, axis: int,
                  pieces: Sequence[Tuple[_Segment, _SlotResult]],
                  target: Optional[_OutputTarget],
                  leases: List[np.ndarray]
                  ) -> Tuple[np.ndarray, int, int]:
        """In-place assembly of one partitionable output.

        Returns (array, bytes copied here, bytes already direct-written).
        Segments that wrote into the target buffer during compute are
        skipped; anything else is packed with a single conversion+copy
        per part (no ``np.asarray`` round trip, no concat temporary)."""
        parts = [res.outputs[name] for _, res in pieces]
        sizes = [int(np.shape(p)[axis]) for p in parts]
        if target is not None:
            expected = all(
                s == seg.units * target.epu
                for s, (seg, _) in zip(sizes, pieces))
            if expected and target.buffer.shape[axis] == sum(sizes):
                copied = direct = 0
                for (seg, res), p, s in zip(pieces, parts, sizes):
                    off = seg.start * target.epu
                    idx = [slice(None)] * target.buffer.ndim
                    idx[axis] = slice(off, off + s)
                    n = s * int(np.prod(target.buffer.shape)
                                // max(target.buffer.shape[axis], 1)
                                ) * target.buffer.itemsize
                    if name in res.written:
                        direct += n
                        continue
                    target.buffer[tuple(idx)] = p
                    copied += n
                return target.buffer, copied, direct
        # no (usable) target: learn the shape, pack into a reusable buffer
        first = parts[0]
        shape = list(np.shape(first))
        shape[axis] = sum(sizes)
        dtype = np.result_type(*[getattr(p, "dtype", None)
                                 or np.asarray(p).dtype for p in parts])
        buf = self._get_buffer(name, tuple(shape), dtype, leases)
        off = 0
        copied = 0
        for p, s in zip(parts, sizes):
            idx = [slice(None)] * buf.ndim
            idx[axis] = slice(off, off + s)
            buf[tuple(idx)] = p
            copied += buf[tuple(idx)].nbytes
            off += s
        return buf, copied, 0

    # -- residency -------------------------------------------------------------
    def _make_resident(self, sct: SCT, part: ConcretePartitioning,
                       done: Sequence[Tuple[_Segment, _SlotResult]],
                       prev: Optional[ResidentPartition],
                       inherited_extras: Dict[str, Any]) -> ResidentPartition:
        """Package a clean run's slot-local outputs as a resident handle.

        Vectors produced by *earlier* chain steps but not re-produced here
        are carried forward — slot-locally when ``prev`` is compatible
        (the layouts are identical by construction), as whole arrays via
        ``extras`` otherwise — so any later step can still consume them.
        """
        produced = _produced_names(sct)
        meta: Dict[str, Tuple[int, int]] = {}
        extras: Dict[str, Any] = {
            k: v for k, v in inherited_extras.items() if k not in produced}
        for name in produced:
            if name in self.merges:
                parts = [res.outputs[name] for _, res in done
                         if name in res.outputs]
                if parts:
                    extras[name] = self.merges[name](parts)
                continue
            ae = self._axis_epu(sct, part, name)
            if ae is not None and all(
                    getattr(res.outputs.get(name), "ndim", 0) >= 1
                    for _, res in done if name in res.outputs):
                meta[name] = ae
            else:
                parts = [res.outputs[name] for _, res in done
                         if name in res.outputs]
                if parts:
                    extras[name] = parts[0]
        envs: List[Dict[str, Any]] = []
        for i, (seg, res) in enumerate(done):
            env = {n: res.outputs[n] for n in meta if n in res.outputs}
            if prev is not None:
                for n, ae in prev.meta.items():
                    if n in produced or n in env:
                        continue
                    carried = prev.envs[i].get(n) if i < len(prev.envs) \
                        else None
                    if carried is not None:
                        env[n] = carried
                        meta.setdefault(n, ae)
            envs.append(env)
        layout = tuple((seg.start, seg.units) for seg, _ in done)
        return ResidentPartition(part=part, layout=layout, envs=envs,
                                 meta=meta, extras=extras,
                                 executor=self, sct=sct)


def _produced_names(sct: SCT) -> List[str]:
    names: List[str] = []
    for leaf in sct.leaves():
        for a in leaf.spec.outputs:
            if a.name not in names:
                names.append(a.name)
    # include function-reduction outputs of MapReduce nodes
    from repro.core.skeletons import MapReduce
    stack = [sct]
    while stack:
        n = stack.pop()
        if isinstance(n, MapReduce) and n.host_side_reduction:
            src = n.map_stage.output_names()
            if len(src) == 1:
                dst = n.out_name or f"{src[0]}_reduced"
                if dst not in names:
                    names.append(dst)
        stack.extend(n.children())
    return names


class Future:
    """Marrow's asynchronous execution handle (paper Table 1).

    ``get`` re-raises executor failures as
    :class:`~repro.core.faults.ExecutionError` with the failing slot /
    device identity attached, instead of a bare pool exception.
    """

    def __init__(self, inner: cf.Future, deadline: Optional[float] = None):
        self._inner = inner
        self._deadline = deadline

    def get(self, timeout: Optional[float] = None):
        timeout = timeout if timeout is not None else self._deadline
        try:
            return self._inner.result(timeout)
        except ExecutionError:
            raise
        except cf.TimeoutError:
            raise ExecutionError(
                f"request did not complete within {timeout}s") from None
        except Exception as e:
            raise ExecutionError(
                f"execution failed: {type(e).__name__}: {e}",
                getattr(e, "records", [])) from e

    def done(self) -> bool:
        return self._inner.done()


class _HandleFuture:
    """``concurrent.futures``-shaped view of one :class:`GraphHandle`
    node (duck-typed inner future for :class:`Future`)."""

    def __init__(self, handle: GraphHandle, extract: Callable[..., Any]):
        self._handle = handle
        self._extract = extract

    def result(self, timeout: Optional[float] = None):
        self._handle.result(timeout)    # raises on failure / wait timeout
        return self._extract(self._handle)

    def done(self) -> bool:
        return self._handle.done()


class Session:
    """User-facing facade: SCT.run()/submit() -> Future over a Scheduler.

    Usable as a context manager (``with Session(sched) as s: ...`` shuts
    the request queue down on exit).  Requests are admitted concurrently
    — :meth:`submit` takes a whole :class:`~repro.core.graph.JobGraph`
    and returns a :class:`~repro.core.graph.GraphHandle`; ``run`` and
    ``run_chain`` are thin wrappers over one-node / linear graphs and
    keep their historical signatures and ``Future`` semantics.  At most
    ``max_inflight`` graphs may be unsettled at once; beyond that,
    ``submit`` blocks (backpressure) until one completes.

    Recurrent submissions are transparent to callers but cheaper: a
    structurally identical graph over same-shaped arrays is served from
    the scheduler's whole-graph plan cache (every node pre-planned, no
    decide/plan lock traffic), and — when the scheduler was built with
    ``fusion_window > 0`` — identical single-node graphs submitted
    within the window coalesce into one wider run whose merged output
    is sliced back per request.  Both paths settle the returned
    ``GraphHandle``/``Future`` exactly as the ordinary path does, with
    bit-identical outputs.

    ``run`` accepts a request-level ``deadline`` (seconds, enforced
    across retries and by ``Future.get``) and ``retries`` with
    exponential backoff on terminal
    :class:`~repro.core.faults.ExecutionError`; each backoff pause is
    capped by the remaining deadline.  ``shutdown`` drains in-flight
    requests, then closes the scheduler's graph pool and executor
    (persistent work queues, reusable output buffers — see
    :class:`ThreadedExecutor`); it is idempotent.

    ``telemetry`` installs a shared :class:`~repro.core.telemetry.Telemetry`
    bundle across the scheduler, executor, health tracker and balancer;
    :meth:`metrics`, :meth:`counters`, :meth:`export_trace` and
    :meth:`prometheus` expose what it collected.  Without one, the
    pipeline runs on the no-op ``NULL_TELEMETRY`` (off-by-default cheap).
    """

    def __init__(self, scheduler, *,
                 telemetry: Optional[Telemetry] = None,
                 max_inflight: int = 8):
        self.scheduler = scheduler
        if telemetry is not None and hasattr(scheduler, "attach_telemetry"):
            scheduler.attach_telemetry(telemetry)
        self.telemetry = getattr(scheduler, "telemetry", None) \
            or telemetry or NULL_TELEMETRY
        if max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        self.max_inflight = max_inflight
        self._inflight = threading.BoundedSemaphore(max_inflight)
        self._closed = False

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown()

    # -- graph pipeline -------------------------------------------------------
    def submit(self, graph: JobGraph, *, deadline: Optional[float] = None,
               retries: int = 0, retry_backoff: float = 0.05,
               **arrays) -> GraphHandle:
        """Submit a JobGraph for concurrent execution; returns its handle.

        Blocks while ``max_inflight`` earlier submissions are still
        unsettled (backpressure); per-node ``retries`` / ``deadline``
        semantics match :meth:`run`."""
        if self._closed:
            raise RuntimeError("session is shut down")
        self._inflight.acquire()
        try:
            handle = self.scheduler.submit(
                graph, arrays, deadline=deadline, retries=retries,
                retry_backoff=retry_backoff)
        except BaseException:
            self._inflight.release()
            raise
        handle.add_done_callback(lambda _h: self._inflight.release())
        return handle

    def gather(self, *handles: GraphHandle,
               timeout: Optional[float] = None) -> List[GraphResult]:
        """Block for a set of submitted graphs; returns their results in
        argument order (raising the first failure encountered)."""
        return [h.result(timeout) for h in handles]

    def run(self, sct: SCT, *, deadline: Optional[float] = None,
            retries: int = 0, retry_backoff: float = 0.05,
            **arrays) -> Future:
        graph = JobGraph()
        name = graph.add(sct)
        handle = self.submit(graph, deadline=deadline, retries=retries,
                             retry_backoff=retry_backoff, **arrays)
        return Future(_HandleFuture(handle, lambda h: h.runs[name]),
                      deadline=deadline)

    def run_chain(self, scts: Sequence[SCT], *, deadline: Optional[float] = None,
                  retries: int = 0, **arrays) -> Future:
        """Asynchronously run a compound SCT chain with partitioned
        residency between steps (a linear ``JobGraph``: residency flows
        along its chain edges exactly as in ``Scheduler.run_chain``)."""
        graph = JobGraph()
        names = graph.add_chain(list(scts))
        handle = self.submit(graph, deadline=deadline, retries=retries,
                             **arrays)
        return Future(
            _HandleFuture(handle, lambda h: [h.runs[n] for n in names]),
            deadline=deadline)

    # -- observability --------------------------------------------------------
    def metrics(self) -> Dict[str, Any]:
        """JSON snapshot of every metric series the pipeline recorded."""
        return self.telemetry.metrics.snapshot()

    def prometheus(self) -> str:
        """Prometheus text-format dump of the metrics registry."""
        return self.telemetry.metrics.to_prometheus()

    def counters(self) -> Dict[str, float]:
        """Namespaced pipeline counters (see ``Scheduler.counters``)."""
        counters = getattr(self.scheduler, "counters", None)
        return counters() if counters is not None else {}

    def events(self, kind: Optional[str] = None):
        """Recent structured events, optionally filtered by kind prefix."""
        return self.telemetry.events.records(kind)

    def export_trace(self, path: str) -> Dict[str, Any]:
        """Write the Chrome/Perfetto ``trace.json``; returns the object."""
        return self.telemetry.export_trace(path)

    def shutdown(self) -> None:
        """Drain in-flight graphs and release every execution resource.

        Idempotent — repeated calls (or a context-manager exit after an
        explicit shutdown) are no-ops."""
        if self._closed:
            return
        self._closed = True
        close = getattr(self.scheduler, "close", None)
        if close is not None:
            close()                     # drains, then closes the executor
            return
        exclose = getattr(getattr(self.scheduler, "executor", None),
                          "close", None)
        if exclose is not None:
            exclose()
