"""MarrowTPU core — the paper's contribution as a composable JAX module.

Layers (paper Fig. 2):
  Library  — :mod:`repro.core.skeletons` (SCTs), :mod:`repro.core.spec`
             (kernel interfaces, Vector/Scalar types, traits, merges).
  Runtime  — :mod:`repro.core.scheduler` (Fig. 4 workflow),
             :mod:`repro.core.faults` (fault taxonomy, deterministic
             injection, retry policy, device-health quarantine),
             :mod:`repro.core.decomposition` (locality-aware domain
             decomposition), :mod:`repro.core.distribution` (binary-search
             workload distribution), :mod:`repro.core.autotuner`
             (Algorithm 1), :mod:`repro.core.knowledge_base` (profiles +
             RBF/NN derivation), :mod:`repro.core.load_balancer` (lbt),
             :mod:`repro.core.platforms` (fission / overlap back-ends),
             :mod:`repro.core.executor` / :mod:`repro.core.simulator`,
             :mod:`repro.core.telemetry` (tracing, metrics, event log).
"""
from repro.core.decomposition import (ConcretePartitioning, DecompositionError,
                                      DecompositionPlan, ExecutionSlot,
                                      build_plan, validate)
from repro.core.distribution import (AdaptiveBinarySearch, Distribution,
                                     WorkloadDistributionGenerator,
                                     balance_until_stable, run_binary_search)
from repro.core.executor import (ExecResult, Future, ResidentPartition,
                                 Session, ThreadedExecutor)
from repro.core.graph import (GraphDriver, GraphError, GraphHandle,
                              GraphResult, JobGraph, JobNode)
from repro.core.faults import (DeviceHealth, ExecutionError, FaultInjector,
                               FaultPolicy, FaultRecord, PartitionLost,
                               SlotFailure, SlotTimeout)
from repro.core.knowledge_base import (KnowledgeBase, Origin, PlatformConfig,
                                       Profile, RBFNetwork)
from repro.core.load_balancer import ExecutionStats, LoadBalancer
from repro.core.platforms import (AcceleratorPlatform, DeviceInfo,
                                  FISSION_LEVELS, HostPlatform)
from repro.core.scheduler import (GraphPlan, GraphPlanCache, NodePlan,
                                  PlanCache, ScheduledRun, Scheduler,
                                  infer_workload)
from repro.core.simulator import CostModel, SimDevice, SimulatedExecutor
from repro.core.skeletons import (SCT, KernelNode, Loop, LoopState, Map,
                                  MapReduce, Pipeline, kernel)
from repro.core.spec import (ArgSpec, KernelSpec, MERGE_ADD, MERGE_DIV,
                             MERGE_MUL, MERGE_SUB, Trait, Transfer, Workload,
                             scalar, vector)
from repro.core.telemetry import (Event, EventLog, MetricsRegistry,
                                  NULL_TELEMETRY, Telemetry, Tracer,
                                  metrics_block, validate_chrome_trace)
from repro.core.autotuner import TunerParams, TuneResult, build_profile

__all__ = [n for n in dir() if not n.startswith("_")]
