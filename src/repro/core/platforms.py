"""Execution platforms (paper Sec. 2.2) — the technology-bound lower layer.

The Marrow runtime delegates device specificities to *execution platforms*:

* ``CPUExecutionPlatform`` — OpenCL **device fission**: splits a multi-core
  CPU device into sub-devices along cache/NUMA affinity domains
  (L1 < L2 < L3 < NUMA < NO_FISSION) to leverage data locality.
* ``GPUExecutionPlatform`` — **multi-buffering / overlap**: N in-flight
  executions per GPU so communication overlaps computation, plus the
  occupancy-ordered work-group size candidates.

TPU adaptation (see DESIGN.md Sec. 2):

* :class:`HostPlatform` keeps the paper's fission semantics. Its affinity
  levels map onto the ICI/host hierarchy of a TPU slice — fission level
  ``L1`` = one execution slot per chip, ``L2`` = per pair, ``L3`` = per
  host (8 chips), ``NUMA`` = per 32-chip island, ``NO_FISSION`` = the
  whole slice as one slot.  On this CPU-only container the same levels
  split the host cores' partition count for the real (timed) executor.
* :class:`AcceleratorPlatform` maps overlap onto the in-flight microbatch
  depth (GPU multi-buffering == TPU grad-accumulation chunks whose
  collectives overlap the next chunk's compute).

Install-time calibration (paper: SHOC suite) is
:func:`AcceleratorPlatform.calibrate` — relative throughput scores that
drive the *static* intra-class distribution of Sec. 3.2.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.occupancy import BlockScore, candidates
from repro.core.spec import KernelSpec

#: Fission levels in the paper's search order (L1 first — most sub-devices,
#: most locality — down to NO_FISSION).
FISSION_LEVELS = ("L1", "L2", "L3", "NUMA", "NO_FISSION")


@dataclasses.dataclass(frozen=True)
class DeviceInfo:
    """One schedulable device (or device class member)."""

    name: str
    kind: str                  # "cpu" | "gpu" | "tpu"
    compute_units: int = 1     # cores / chips in the device
    peak_flops: float = 197e12     # bf16, TPU v5e default
    hbm_bw: float = 819e9
    link_bw: float = 50e9
    throughput: float = 1.0    # calibrated relative score (SHOC analogue)


@dataclasses.dataclass(frozen=True)
class FissionConfig:
    level: str
    subdevices: int            # execution slots the level yields


class HostPlatform:
    """CPU/slow-class platform: fission by affinity domain.

    ``topology`` maps each supported fission level to the number of
    sub-devices it yields (paper Sec. 4.1 example: 64-core 4-socket Opteron
    -> L1:64? the paper's table uses L2:32, L3:8, NUMA:4).  Levels absent
    from the map are unsupported by the hardware.
    """

    def __init__(self, device: DeviceInfo,
                 topology: Optional[Dict[str, int]] = None):
        self.device = device
        cu = device.compute_units
        self.topology: Dict[str, int] = topology or {
            "L1": cu, "L2": max(cu // 2, 1), "L3": max(cu // 8, 1),
            "NUMA": max(cu // 16, 1), "NO_FISSION": 1,
        }
        self._level = "NO_FISSION"

    # paper: CPUExecutionPlatform.getConfigurations(SCT, args)
    def get_configurations(self, sct=None, arguments=None) -> List[FissionConfig]:
        return [FissionConfig(lv, self.topology[lv]) for lv in FISSION_LEVELS
                if lv in self.topology]

    def configure(self, level: str) -> int:
        """Apply a fission level; returns the parallelism it contributes."""
        if level not in self.topology:
            raise ValueError(f"unsupported fission level {level}")
        self._level = level
        return self.topology[level]

    @property
    def level(self) -> str:
        return self._level

    @property
    def parallelism(self) -> int:
        return self.topology[self._level]


class AcceleratorPlatform:
    """GPU/fast-class platform: overlap depth + block-size candidates."""

    def __init__(self, devices: Sequence[DeviceInfo], *, max_overlap: int = 8,
                 occupancy_threshold: float = 0.80):
        if not devices:
            raise ValueError("AcceleratorPlatform needs >= 1 device")
        self.devices = list(devices)
        self.max_overlap = max_overlap
        self.occupancy_threshold = occupancy_threshold
        self._overlap = 1

    # paper: GPUExecutionPlatform.getConfigurations -> ({overlaps}, {wgs})
    def get_configurations(self, sct=None, arguments=None,
                           domain_size: int = 1 << 20
                           ) -> Tuple[List[int], Dict[str, List[BlockScore]]]:
        overlaps = list(range(1, self.max_overlap + 1))
        wgs: Dict[str, List[BlockScore]] = {}
        specs: Iterable[KernelSpec] = (sct.kernel_specs() if sct is not None
                                       else [])
        for spec in specs:
            wgs[spec.name] = candidates(
                spec, domain_size,
                cores=sum(d.compute_units for d in self.devices),
                threshold=self.occupancy_threshold)
        return overlaps, wgs

    def configure(self, overlap: int) -> int:
        """Set the overlap factor; returns contributed parallelism
        (paper: #GPUs x overlap concurrent executions)."""
        if not 1 <= overlap <= self.max_overlap:
            raise ValueError(f"overlap {overlap} out of range")
        self._overlap = overlap
        return len(self.devices) * overlap

    @property
    def overlap(self) -> int:
        return self._overlap

    @property
    def parallelism(self) -> int:
        return len(self.devices) * self._overlap

    # -- install-time calibration (SHOC analogue) ---------------------------
    def calibrate(self, workload: Optional[Callable[[DeviceInfo], float]] = None
                  ) -> List[float]:
        """Relative throughput per device, for the static intra-class split.

        With no measurable hardware (CPU-only container) the calibration
        falls back to the analytic model: peak_flops as the score.  When a
        ``workload`` timer is supplied (real hardware), scores are the
        inverse measured times.
        """
        if workload is None:
            scores = [d.peak_flops * d.throughput for d in self.devices]
        else:
            times = [max(workload(d), 1e-12) for d in self.devices]
            scores = [1.0 / t for t in times]
        tot = sum(scores)
        return [s / tot for s in scores]


def timed(fn: Callable[[], None], *, repeats: int = 3) -> float:
    """Best-of-N wall-clock timer used by calibration and the autotuner."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best
