"""Observability layer: structured tracing, metrics, event log.

The paper's scheduler is a *feedback* system — per-device times feed the
lbt detector, the adaptive binary search and the knowledge base — so
every interesting decision (plan-cache miss, repartition retry,
quarantine, balance operation) happens deep inside the run loop where
``ExecutionStats`` alone cannot explain it.  This module provides the
three standard observability primitives, dependency-free:

:class:`Tracer`
    Nested spans with monotonic timestamps and structured attributes.
    Span enter/exit append Chrome-trace ``B``/``E`` events (per-thread
    ordering makes the pairs nest correctly by construction);
    :meth:`Tracer.record` adds pre-timed spans from a *virtual* clock —
    the :class:`~repro.core.simulator.SimulatedExecutor` uses it to lay
    its analytic per-slot times on a deterministic timeline.  The
    buffer exports as Chrome/Perfetto ``trace.json``
    (``chrome://tracing`` / https://ui.perfetto.dev).

:class:`MetricsRegistry`
    Counters, gauges and histograms with optional labels, a
    Prometheus-style text dump (:meth:`~MetricsRegistry.to_prometheus`)
    and a JSON :meth:`~MetricsRegistry.snapshot`.  The recurrent-graph
    fast path reports through ``graph_plan_cache_{hits,misses,
    invalidations}_total`` and cross-request fusion through
    ``fused_requests_total`` / ``fused_batches_total``, alongside the
    per-run ``scheduler_actions_total`` labels ``action="preplanned"``
    and ``action="fused"`` (see the scheduler module docstring for the
    semantics of both paths).

:class:`EventLog`
    Bounded ring buffer of structured events with pluggable sinks and a
    stdlib-``logging`` bridge.  Warning-and-above events are forwarded
    to ``logging`` even when telemetry is disabled, so operational
    signals (device quarantine) are never silently dropped.

:class:`Telemetry` bundles the three and is what the Scheduler,
executors, :class:`~repro.core.faults.DeviceHealth` and
:class:`~repro.core.load_balancer.LoadBalancer` share (see
``Scheduler(telemetry=...)`` / ``Session(telemetry=...)``).

Cost discipline: telemetry is **off by default** and the disabled path
must be negligible — ``NULL_TELEMETRY`` hands out shared no-op span /
metric singletons whose enter/exit/inc are empty methods (no
allocation, no locks, no clock reads); ``tests/test_telemetry.py``
enforces a per-span cost bound with a microbenchmark.

Determinism: all timestamps come from the injectable ``clock``
(default ``time.perf_counter``); with a counting clock and the seeded
simulator the full event stream is reproducible bit-for-bit.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import logging
import threading
import time
from typing import (Any, Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple)

LOGGER_NAME = "repro.telemetry"

_LEVELS = {"debug": logging.DEBUG, "info": logging.INFO,
           "warning": logging.WARNING, "error": logging.ERROR,
           "critical": logging.CRITICAL}


# ---------------------------------------------------------------------------
# Tracing
# ---------------------------------------------------------------------------

class _NullSpan:
    """Shared no-op span: zero-allocation context manager."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def note(self, **attrs) -> None:
        """No-op counterpart of :meth:`_Span.note`."""


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span; ``with tracer.span(...)`` emits a B/E event pair."""

    __slots__ = ("_tracer", "name", "attrs", "_late")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self._late: Optional[Dict[str, Any]] = None

    def note(self, **attrs) -> None:
        """Attach attributes discovered mid-span (exported on the E event)."""
        if self._late is None:
            self._late = {}
        self._late.update(attrs)

    def __enter__(self) -> "_Span":
        self._tracer._emit("B", self.name, self.attrs)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        late = self._late
        if exc_type is not None:
            late = dict(late or {})
            late["error"] = exc_type.__name__
        self._tracer._emit("E", self.name, late)
        return False


class Tracer:
    """Chrome-trace span recorder (B/E pairs + instants + virtual spans)."""

    enabled = True

    def __init__(self, *, clock: Callable[[], float] = time.perf_counter,
                 capacity: int = 100_000):
        self.clock = clock
        self.capacity = capacity
        self.dropped = 0
        self._epoch = clock()
        self._events: List[Dict[str, Any]] = []
        self._tids: Dict[int, int] = {}
        self._lock = threading.Lock()

    # -- recording -----------------------------------------------------------
    def span(self, name: str, **attrs) -> _Span:
        return _Span(self, name, attrs)

    def instant(self, name: str, **attrs) -> None:
        """A point-in-time marker (Chrome phase ``i``)."""
        ev = {"name": name, "ph": "i", "ts": self._ts(), "pid": 0,
              "tid": self._tid(), "s": "t"}
        if attrs:
            ev["args"] = attrs
        self._append(ev)

    def record(self, name: str, start_us: float, duration_us: float,
               *, tid: int = 0, **attrs) -> None:
        """Add a pre-timed span (virtual timeline, e.g. simulated slots).

        ``start_us`` / ``duration_us`` are microseconds on the caller's
        own timeline; exported as a Chrome complete (``X``) event.
        """
        ev = {"name": name, "ph": "X", "ts": float(start_us),
              "dur": max(float(duration_us), 0.0), "pid": 0, "tid": tid}
        if attrs:
            ev["args"] = attrs
        self._append(ev)

    # -- internals -----------------------------------------------------------
    def _ts(self) -> float:
        return (self.clock() - self._epoch) * 1e6      # microseconds

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def _emit(self, ph: str, name: str,
              attrs: Optional[Dict[str, Any]]) -> None:
        ev: Dict[str, Any] = {"name": name, "ph": ph, "ts": self._ts(),
                              "pid": 0, "tid": self._tid()}
        if attrs:
            ev["args"] = attrs
        self._append(ev)

    def _append(self, ev: Dict[str, Any]) -> None:
        # bound the buffer: drop new events past capacity (keeping the
        # prefix preserves already-matched B/E pairs)
        if len(self._events) >= self.capacity:
            self.dropped += 1
            return
        self._events.append(ev)

    # -- export --------------------------------------------------------------
    def events(self) -> List[Dict[str, Any]]:
        return list(self._events)

    def clear(self) -> None:
        self._events = []
        self.dropped = 0

    def chrome_trace(self) -> Dict[str, Any]:
        """The Chrome/Perfetto ``trace.json`` object.

        Spans still open at export time are closed with a synthetic E
        event so the file always validates (matched B/E pairs)."""
        events = list(self._events)
        stacks: Dict[Tuple[int, int], List[Dict[str, Any]]] = {}
        for e in events:
            key = (e["pid"], e["tid"])
            if e["ph"] == "B":
                stacks.setdefault(key, []).append(e)
            elif e["ph"] == "E" and stacks.get(key):
                stacks[key].pop()
        now = self._ts()
        for key, open_spans in stacks.items():
            for b in reversed(open_spans):
                events.append({"name": b["name"], "ph": "E",
                               "ts": max(now, b["ts"]), "pid": key[0],
                               "tid": key[1],
                               "args": {"unterminated": True}})
        return {"traceEvents": events, "displayTimeUnit": "ms"}


class _NullTracer(Tracer):
    """Disabled tracer: every operation is a no-op returning singletons."""

    enabled = False

    def __init__(self):
        super().__init__(clock=lambda: 0.0, capacity=0)

    def span(self, name: str, **attrs) -> _NullSpan:   # type: ignore[override]
        return _NULL_SPAN

    def instant(self, name: str, **attrs) -> None:
        pass

    def record(self, name: str, start_us: float, duration_us: float,
               *, tid: int = 0, **attrs) -> None:
        pass


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

#: default histogram buckets (seconds-oriented, log-spaced)
DEFAULT_BUCKETS = (1e-5, 1e-4, 1e-3, 1e-2, 0.1, 1.0, 10.0, 60.0)


class Counter:
    __slots__ = ("value",)
    kind = "counter"

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def snapshot(self):
        return self.value


class Gauge:
    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def snapshot(self):
        return self.value


class Histogram:
    __slots__ = ("buckets", "counts", "count", "sum")
    kind = "histogram"

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * (len(self.buckets) + 1)    # +inf tail
        self.count = 0
        self.sum = 0.0

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def snapshot(self):
        return {"count": self.count, "sum": self.sum,
                "buckets": {str(b): c for b, c in
                            zip(self.buckets + ("+Inf",),
                                _cumulative(self.counts))}}


def _cumulative(counts: Sequence[int]) -> List[int]:
    out, acc = [], 0
    for c in counts:
        acc += c
        out.append(acc)
    return out


class _NullMetric:
    """Shared no-op counter/gauge/histogram for disabled telemetry."""

    __slots__ = ()
    kind = "null"
    value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass

    def snapshot(self):
        return 0.0


_NULL_METRIC = _NullMetric()


class MetricsRegistry:
    """Named counters / gauges / histograms with optional labels.

    A metric series is identified by ``(name, sorted label items)``;
    lookups get-or-create, so instrumentation sites never need
    registration boilerplate:

        registry.counter("retries_total").inc()
        registry.counter("device_busy_seconds_total", device="gpu0").inc(t)
    """

    enabled = True

    def __init__(self):
        self._series: "collections.OrderedDict[Tuple[str, Tuple], Any]" = \
            collections.OrderedDict()
        self._lock = threading.Lock()

    def _get(self, name: str, labels: Dict[str, Any], factory):
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        m = self._series.get(key)
        if m is None:
            with self._lock:
                m = self._series.setdefault(key, factory())
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(name, labels, Gauge)

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        return self._get(name, labels, lambda: Histogram(buckets))

    # -- export --------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """JSON-serialisable dump: ``name{k=v,...} -> value`` flat map."""
        out: Dict[str, Any] = {}
        for (name, labels), metric in self._series.items():
            key = name
            if labels:
                key += "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"
            out[key] = metric.snapshot()
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (untyped label escaping)."""
        lines: List[str] = []
        typed: set = set()
        for (name, labels), metric in self._series.items():
            pname = name.replace(".", "_").replace("-", "_")
            if pname not in typed:
                lines.append(f"# TYPE {pname} {metric.kind}")
                typed.add(pname)
            lab = ""
            if labels:
                lab = "{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}"
            if isinstance(metric, Histogram):
                cum = _cumulative(metric.counts)
                for b, c in zip(metric.buckets + ("+Inf",), cum):
                    extra = f'le="{b}"'
                    blab = ("{" + ",".join(f'{k}="{v}"' for k, v in labels)
                            + ("," if labels else "") + extra + "}") \
                        if labels else "{" + extra + "}"
                    lines.append(f"{pname}_bucket{blab} {c}")
                lines.append(f"{pname}_sum{lab} {metric.sum}")
                lines.append(f"{pname}_count{lab} {metric.count}")
            else:
                lines.append(f"{pname}{lab} {metric.snapshot()}")
        return "\n".join(lines) + ("\n" if lines else "")


class _NullMetricsRegistry(MetricsRegistry):
    enabled = False

    def counter(self, name: str, **labels):    # type: ignore[override]
        return _NULL_METRIC

    def gauge(self, name: str, **labels):      # type: ignore[override]
        return _NULL_METRIC

    def histogram(self, name: str, buckets=DEFAULT_BUCKETS,
                  **labels):                   # type: ignore[override]
        return _NULL_METRIC


# ---------------------------------------------------------------------------
# Event log
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Event:
    """One structured event (fault, health transition, balancer op, ...)."""

    seq: int
    ts: float                    # seconds on the telemetry clock
    kind: str                    # e.g. "health.quarantined"
    level: str                   # "debug" | "info" | "warning" | "error"
    message: str = ""
    fields: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> Dict[str, Any]:
        return {"seq": self.seq, "ts": self.ts, "kind": self.kind,
                "level": self.level, "message": self.message,
                **self.fields}


class EventLog:
    """Bounded ring buffer of :class:`Event` with sinks + logging bridge.

    ``sink`` callables receive every event (exceptions are contained so
    a broken sink cannot fail the run loop).  With ``bridge=True``
    every event is forwarded to the stdlib logger ``repro.telemetry``
    at its own level; a *disabled* log still bridges warning-and-above
    events — operational signals like device quarantine must reach the
    operator even with telemetry off.
    """

    enabled = True

    def __init__(self, *, capacity: int = 1024,
                 sink: Optional[Callable[[Event], None]] = None,
                 bridge: bool = True,
                 clock: Callable[[], float] = time.perf_counter,
                 logger: Optional[logging.Logger] = None):
        self.capacity = capacity
        self.bridge = bridge
        self.clock = clock
        self._epoch = clock()
        self._logger = logger or logging.getLogger(LOGGER_NAME)
        self._buffer: "collections.deque[Event]" = \
            collections.deque(maxlen=capacity)
        self._sinks: List[Callable[[Event], None]] = [sink] if sink else []
        self._seq = 0
        self._lock = threading.Lock()

    def add_sink(self, sink: Callable[[Event], None]) -> None:
        self._sinks.append(sink)

    def emit(self, kind: str, *, level: str = "info", message: str = "",
             **fields) -> Optional[Event]:
        if not self.enabled:
            if self.bridge and _LEVELS.get(level, 0) >= logging.WARNING:
                self._logger.log(_LEVELS[level], "%s %s%s", kind, message,
                                 f" {fields}" if fields else "")
            return None
        with self._lock:
            seq = self._seq
            self._seq += 1
        ev = Event(seq=seq, ts=self.clock() - self._epoch, kind=kind,
                   level=level, message=message, fields=fields)
        self._buffer.append(ev)
        for sink in self._sinks:
            try:
                sink(ev)
            except Exception:           # a broken sink must not fail runs
                logging.getLogger(LOGGER_NAME).exception(
                    "telemetry sink raised")
        if self.bridge:
            self._logger.log(_LEVELS.get(level, logging.INFO),
                             "%s %s%s", kind, message,
                             f" {fields}" if fields else "")
        return ev

    def records(self, kind: Optional[str] = None) -> List[Event]:
        evs = list(self._buffer)
        if kind is not None:
            evs = [e for e in evs if e.kind == kind
                   or e.kind.startswith(kind + ".")]
        return evs

    def __len__(self) -> int:
        return len(self._buffer)


class _NullEventLog(EventLog):
    """Disabled event log: buffers nothing, still bridges warnings."""

    enabled = False

    def __init__(self):
        super().__init__(capacity=0, clock=lambda: 0.0)


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------

class Telemetry:
    """Tracer + metrics + event log bundle shared across the pipeline.

    ``Telemetry()`` is the enabled collector; :data:`NULL_TELEMETRY`
    (also ``Telemetry.disabled()``) is the shared off-by-default
    instance whose operations are no-ops (except warning-level event
    bridging, see :class:`EventLog`).
    """

    def __init__(self, *, enabled: bool = True,
                 clock: Callable[[], float] = time.perf_counter,
                 span_capacity: int = 100_000, event_capacity: int = 1024,
                 sink: Optional[Callable[[Event], None]] = None,
                 log_bridge: bool = True):
        self.enabled = enabled
        if enabled:
            self.tracer: Tracer = Tracer(clock=clock,
                                         capacity=span_capacity)
            self.metrics: MetricsRegistry = MetricsRegistry()
            self.events: EventLog = EventLog(capacity=event_capacity,
                                             sink=sink, bridge=log_bridge,
                                             clock=clock)
        else:
            self.tracer = _NullTracer()
            self.metrics = _NullMetricsRegistry()
            self.events = _NullEventLog()

    @staticmethod
    def disabled() -> "Telemetry":
        return NULL_TELEMETRY

    # -- export --------------------------------------------------------------
    def export_trace(self, path: str) -> Dict[str, Any]:
        """Write the Chrome ``trace.json`` to ``path``; returns the object.

        Load it in ``chrome://tracing`` or https://ui.perfetto.dev."""
        trace = self.tracer.chrome_trace()
        with open(path, "w") as f:
            json.dump(trace, f)
        return trace

    def snapshot(self) -> Dict[str, Any]:
        """One JSON-serialisable blob: metrics + recent events."""
        return {"metrics": self.metrics.snapshot(),
                "events": [e.as_dict() for e in self.events.records()]}


#: the shared disabled instance — the default for every instrumented class
NULL_TELEMETRY = Telemetry(enabled=False)


# ---------------------------------------------------------------------------
# Chrome-trace validation (tests + CI smoke job)
# ---------------------------------------------------------------------------

_REQUIRED_KEYS = ("name", "ph", "ts", "pid", "tid")


def validate_chrome_trace(trace: Any) -> List[str]:
    """Validate a Chrome trace object; returns a list of problems.

    Checks the containership schema (``traceEvents`` list of event
    objects with name/ph/ts/pid/tid), numeric timestamps, ``dur`` on
    complete (``X``) events, and — per ``(pid, tid)`` track — that
    every ``B`` has a matching same-name ``E`` in nesting order.
    """
    errors: List[str] = []
    if not isinstance(trace, dict):
        return ["trace is not a JSON object"]
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    stacks: Dict[Tuple[Any, Any], List[Tuple[str, float]]] = {}
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            errors.append(f"event {i}: not an object")
            continue
        missing = [k for k in _REQUIRED_KEYS if k not in e]
        if missing:
            errors.append(f"event {i}: missing keys {missing}")
            continue
        if not isinstance(e["ts"], (int, float)) or e["ts"] < 0:
            errors.append(f"event {i}: bad ts {e['ts']!r}")
        ph = e["ph"]
        key = (e["pid"], e["tid"])
        if ph == "B":
            stacks.setdefault(key, []).append((e["name"], e["ts"]))
        elif ph == "E":
            stack = stacks.get(key)
            if not stack:
                errors.append(f"event {i}: E '{e['name']}' with no open B "
                              f"on track {key}")
                continue
            name, ts = stack.pop()
            if name != e["name"]:
                errors.append(f"event {i}: E '{e['name']}' closes B "
                              f"'{name}' (mismatched nesting)")
            if isinstance(e["ts"], (int, float)) and e["ts"] < ts:
                errors.append(f"event {i}: E before its B "
                              f"({e['ts']} < {ts})")
        elif ph == "X":
            if not isinstance(e.get("dur"), (int, float)) or e["dur"] < 0:
                errors.append(f"event {i}: X event without numeric dur")
        elif ph not in ("i", "I", "M", "C"):
            errors.append(f"event {i}: unknown phase {ph!r}")
    for key, stack in stacks.items():
        for name, _ in stack:
            errors.append(f"unmatched B '{name}' on track {key}")
    return errors


def metrics_block(telemetry: Telemetry) -> Dict[str, Any]:
    """Schema-stable metrics block for embedding in BENCH_*.json files."""
    return {"schema": "repro.metrics/v1",
            "enabled": telemetry.enabled,
            "metrics": telemetry.metrics.snapshot()}
