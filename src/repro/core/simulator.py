"""Calibrated heterogeneous device-pool simulator.

Scheduling-policy experiments at multi-device scale (the paper's hybrid
CPU+GPU tables, the load-fluctuation adaptation of Fig. 11, pod-scale
straggler studies) cannot be *measured* on this single-core CPU container.
They are evaluated on an analytic simulator that shares the executor
interface, with a cost model calibrated to the paper's hardware ratios:

  slot time =  compute + transfer (+ queue overhead) , where

  * GPU-class slot:  compute = units * flop_u / flops_dev
                     transfer = units * bytes_u / pcie_bw / overlap
                     (multi-buffering hides transfers behind compute)
  * CPU-class slot:  compute = units * flop_u / (flops_core * cores_slot)
                              * locality(level, working_set) * (1 + load)
                     (device fission: per-slot working sets that fit the
                     affinity domain's cache run at a locality bonus;
                     ``load`` models external CPU load fluctuation)

Determinism: multiplicative noise from a seeded Generator; experiments are
reproducible bit-for-bit.  The same model doubles as the *straggler* model
for TPU slices (a slice whose throughput drifts == a loaded CPU).

Failure semantics: the simulator honours the same
:class:`~repro.core.faults.FaultInjector` and retry ladder as the real
:class:`~repro.core.executor.ThreadedExecutor` — injected crashes kill a
slot halfway through its simulated run, injected stalls add
``stall_seconds`` (tripping the watchdog deadline when one is derivable
from ``profile.best_time``), lost unit ranges are re-split across the
surviving slots, and exhausted retries raise
:class:`~repro.core.faults.ExecutionError` — so pod-scale failure and
straggler policies are testable deterministically without hardware.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.decomposition import ConcretePartitioning
from repro.core.faults import (ExecutionError, FaultInjector, FaultPolicy,
                               FaultRecord, split_units)
from repro.core.knowledge_base import Profile
from repro.core.skeletons import SCT
from repro.core.spec import Transfer, Workload
from repro.core.telemetry import NULL_TELEMETRY, Telemetry

#: cache capacity (bytes) of each fission affinity domain — paper Sec. 4.1
#: hardware (AMD Opteron 6272): 16 KiB L1/core, 2 MiB L2/2 cores,
#: 6 MiB L3/8 cores, NUMA = DRAM.
CACHE_BYTES = {"L1": 16 << 10, "L2": 2 << 20, "L3": 6 << 20,
               "NUMA": 1 << 62, "NO_FISSION": 1 << 62}
#: effective-throughput multiplier per fission level, calibrated to the
#: paper's Table 2 (a NO_FISSION device spanning 4 NUMA sockets loses
#: throughput to cross-socket traffic and scheduler thrash; L2-affinity
#: subdevices recover ~3x, L1 splits too fine, NUMA too coarse)
LOCALITY_FACTOR = {"L1": 2.0, "L2": 3.0, "L3": 2.4, "NUMA": 1.5,
                   "NO_FISSION": 1.0}
TILE_BONUS = 1.3                # extra bw when a slot's tile fits its cache
SLOT_OVERHEAD = 2e-4            # per-slot dispatch cost (seconds)


@dataclasses.dataclass
class SimDevice:
    name: str
    kind: str                       # "cpu" | "gpu"
    flops: float                    # effective FLOP/s of the whole device
    mem_bw: float = 50e9            # device memory bandwidth
    pcie_bw: float = 8e9            # host<->device staging bandwidth
    cores: int = 1


@dataclasses.dataclass
class CostModel:
    """Per-domain-unit costs of one SCT execution."""

    flops_per_unit: float
    bytes_per_unit: float
    iterations: float = 1.0         # Loop skeletons repeat the body

    @staticmethod
    def of(sct: SCT, workload: Workload) -> "CostModel":
        units = None
        fl = by = 0.0
        for spec in sct.kernel_specs():
            vec = [a for a in spec.vectors if a.partitionable]
            epu = vec[0].epu if vec else 1
            elems = epu  # elements of one unit along the partition dim
            row = workload.size / max(workload.dims[0], 1)
            fl += spec.flops_per_item * elems * row
            by += spec.bytes_per_item * elems * row
        return CostModel(flops_per_unit=fl, bytes_per_unit=by)


class SimulatedExecutor:
    """Executor-interface analytic simulator."""

    # analytic model: no real buffers to keep slot-resident, so the
    # Scheduler never passes residency kwargs to this executor
    supports_residency = False
    # graphs execute on the simulated timeline (GraphDriver.run_virtual):
    # deterministic per-device-queue list scheduling instead of threads
    virtual_clock = True

    def __init__(self, devices: Sequence[SimDevice], *, seed: int = 0,
                 noise: float = 0.02, compute_outputs: bool = False,
                 cost: Optional[CostModel] = None,
                 injector: Optional[FaultInjector] = None,
                 policy: FaultPolicy = FaultPolicy(),
                 telemetry: Optional[Telemetry] = None):
        self.telemetry = telemetry or NULL_TELEMETRY
        # virtual simulated-time clock (µs): spans are laid on this
        # timeline, so the exported trace is deterministic (seeded
        # jitter only — no wall-clock reads)
        self._vclock_us = 0.0
        self.devices = {d.name.split("/")[0]: d for d in devices}
        self.noise = noise
        self.rng = np.random.default_rng(seed)
        self.compute_outputs = compute_outputs
        self.cpu_load = 0.0              # external load factor (Fig. 11)
        self.cost_override = cost
        self.injector = injector
        self.policy = policy
        self._last_times: List[float] = []
        self._last_n_a = 0
        self.executions = 0
        self.last_failures: List[FaultRecord] = []
        self.last_retries = 0
        self.last_timing: Dict[str, float] = {}
        self.last_merge_bytes = 0
        self.last_resident = None

    # -- knobs -------------------------------------------------------------
    def set_cpu_load(self, load: float) -> None:
        """External CPU load: 0 = idle, 1 = fully contended (x2 slowdown)."""
        self.cpu_load = max(0.0, load)

    @property
    def vclock_us(self) -> float:
        """The virtual clock (µs).  Writable: the graph driver rewinds /
        advances it to each node's dataflow-ready time."""
        return self._vclock_us

    @vclock_us.setter
    def vclock_us(self, value: float) -> None:
        self._vclock_us = float(value)

    # -- Scheduler interface -------------------------------------------------
    def execute(self, sct: SCT, part: ConcretePartitioning,
                arrays: Dict[str, Any], profile: Profile
                ) -> Tuple[Dict[str, Any], List[float]]:
        workload = _workload_of(part)
        cost = self.cost_override or CostModel.of(sct, workload)
        level = profile.config.fission_level
        overlap = max(profile.config.overlap, 1)
        cpu_slots = [s for s in part.slots if s.device_type == "cpu"]
        n_cpu = max(len(cpu_slots), 1)
        deadline = self.policy.deadline(getattr(profile, "best_time", None))

        tel = self.telemetry
        times = [0.0] * len(part.slots)
        records: List[FaultRecord] = []
        retries = 0
        dead: set = set()
        pending: Dict[int, int] = {j: u for j, u in enumerate(part.units)}
        for attempt in range(self.policy.max_attempts):
            round_us = self._vclock_us       # virtual start of this round
            round_max = 0.0
            failed: Dict[int, int] = {}
            for j, units in pending.items():
                slot = part.slots[j]
                dev = self._device_for(slot.device)
                t = self._slot_time(dev, units, cost, level, overlap,
                                    n_cpu_slots=n_cpu)
                kind = (self.injector.decide(slot.device)
                        if self.injector is not None else None)
                if kind == "stall":
                    t += self.injector.stall_seconds
                    if deadline is not None and t > deadline:
                        rec = FaultRecord(
                            slot=j, device=slot.device,
                            device_type=slot.device_type, kind="timeout",
                            attempt=attempt,
                            message="simulated stall tripped watchdog "
                                    f"({deadline:.3f}s)",
                            seconds=deadline)
                        records.append(rec)
                        dead.add(j)
                        failed[j] = units
                        times[j] += deadline
                        round_max = max(round_max, deadline)
                        self._observe_slot(slot, units, deadline, attempt,
                                           round_us, fault=rec)
                        continue
                if kind == "crash":
                    # the slot dies halfway through its simulated run
                    rec = FaultRecord(
                        slot=j, device=slot.device,
                        device_type=slot.device_type, kind="crash",
                        attempt=attempt, message="injected crash",
                        seconds=t * 0.5)
                    records.append(rec)
                    dead.add(j)
                    failed[j] = units
                    times[j] += t * 0.5
                    round_max = max(round_max, t * 0.5)
                    self._observe_slot(slot, units, t * 0.5, attempt,
                                       round_us, fault=rec)
                    continue
                times[j] += t
                round_max = max(round_max, t)
                self._observe_slot(slot, units, t, attempt, round_us)
            self._vclock_us = round_us + round_max * 1e6
            lost_units = sum(u for u in failed.values() if u > 0)
            if not lost_units:
                break
            alive = [j for j in range(len(part.slots)) if j not in dead]
            if not alive:
                raise ExecutionError(
                    "partition lost: no surviving execution slot can adopt "
                    f"{lost_units} domain units", records, attempt + 1)
            if attempt == self.policy.max_attempts - 1:
                raise ExecutionError(
                    f"retries exhausted after {self.policy.max_attempts} "
                    "attempts", records, attempt + 1)
            counts = split_units(lost_units, len(alive))
            pending = {j: u for j, u in zip(alive, counts) if u}
            retries += 1
            tel.events.emit("retry.repartition", lost_units=lost_units,
                            survivors=len(alive), attempt=attempt)

        self.last_failures = records
        self.last_retries = retries
        self._last_times = times
        self._last_n_a = sum(1 for s in part.slots if s.device_type != "cpu")
        self.last_timing = {"pool": 0.0, "dispatch": 0.0, "merge": 0.0,
                            "compute": max(times) if times else 0.0}
        self.last_merge_bytes = 0
        self.executions += 1
        outputs: Dict[str, Any] = {}
        if self.compute_outputs:
            env = dict(arrays)
            outputs = sct.apply(env)
        return outputs, times

    def execute_result(self, sct: SCT, part: ConcretePartitioning,
                       arrays: Dict[str, Any], profile: Profile):
        """Per-call result (``ExecResult``) matching the threaded
        executor's concurrent interface.  The simulator itself is
        single-threaded (graph execution is sequential in virtual time),
        so packaging from the ``last_*`` fields is race-free."""
        from repro.core.executor import ExecResult
        outputs, times = self.execute(sct, part, arrays, profile)
        return ExecResult(
            outputs=outputs, times=times,
            failures=list(self.last_failures), retries=self.last_retries,
            timing=dict(self.last_timing), merge_bytes=0, direct_bytes=0,
            resident=None, n_a=self._last_n_a)

    def _observe_slot(self, slot, units: int, seconds: float, attempt: int,
                      round_us: float,
                      fault: Optional[FaultRecord] = None) -> None:
        """Telemetry for one simulated slot execution.

        Spans are laid on the virtual simulated-time axis (``record``,
        Chrome ``X`` events, one track per physical device) so the
        exported trace depends only on the seeded cost model — fully
        deterministic, no wall-clock reads."""
        tel = self.telemetry
        base = slot.device.split("/")[0]
        tid = list(self.devices).index(base) if base in self.devices else 0
        tel.tracer.record("slot", round_us, seconds * 1e6, tid=tid,
                          device=slot.device, units=units, attempt=attempt,
                          **({"fault": fault.kind} if fault else {}))
        # per-device busy seconds are accounted once, by the Scheduler,
        # from stats.times — identical for both executors
        if fault is not None:
            tel.metrics.counter("faults_total", kind=fault.kind).inc()
            tel.events.emit("fault", level="warning", message=fault.message,
                            device=fault.device, fault_kind=fault.kind,
                            attempt=fault.attempt, slot=fault.slot)

    def last_class_times(self) -> Tuple[float, float]:
        n_a, t = self._last_n_a, self._last_times
        ta = max(t[:n_a]) if n_a else 0.0
        tb = max(t[n_a:]) if len(t) > n_a else 0.0
        return ta, tb

    def synthesise_arrays(self, sct: SCT, workload: Workload
                          ) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for a in sct.free_inputs():
            out[a.name] = (_ShapeStub(workload.dims, workload.itemsize)
                           if a.kind == "vector" else np.float32(1.0))
        return out

    # -- cost model ----------------------------------------------------------
    def _device_for(self, slot_device: str) -> SimDevice:
        base = slot_device.split("/")[0]
        if base in self.devices:
            return self.devices[base]
        # fission sub-device of a CPU
        for d in self.devices.values():
            if slot_device.startswith(d.name):
                return d
        raise KeyError(slot_device)

    def _slot_time(self, dev: SimDevice, units: int, cost: CostModel,
                   level: str, overlap: int, *, n_cpu_slots: int) -> float:
        if units == 0:
            return 0.0
        flops = units * cost.flops_per_unit * cost.iterations
        byts = units * cost.bytes_per_unit
        if dev.kind == "cpu":
            loc = LOCALITY_FACTOR.get(level, 1.0)
            comp = flops / (dev.flops / n_cpu_slots * loc)
            bw = dev.mem_bw / n_cpu_slots * loc
            if byts <= CACHE_BYTES.get(level, 0):
                bw *= TILE_BONUS              # tile fits the affinity cache
            mem = byts / bw
            t = max(comp, mem) * (1.0 + self.cpu_load)
            t += SLOT_OVERHEAD * (1 + 0.02 * n_cpu_slots)   # fission overhead
        else:
            comp = max(flops / dev.flops, byts / dev.mem_bw)
            xfer = byts / dev.pcie_bw
            # multi-buffering: first buffer exposed, the rest overlapped
            t = comp + xfer / overlap + SLOT_OVERHEAD
        jitter = 1.0 + self.noise * float(self.rng.standard_normal())
        return t * max(jitter, 0.5)


@dataclasses.dataclass
class _ShapeStub:
    """Shape-only array stand-in (no allocation) for simulated requests."""

    shape: Tuple[int, ...]
    _itemsize: int = 4

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def dtype(self):
        class _D:
            itemsize = self._itemsize
        return _D()


def _workload_of(part: ConcretePartitioning) -> Workload:
    v = next((v for v in part.plan.vectors.values() if not v.copy), None)
    if v is None:
        return Workload((1,))
    return Workload((v.extent,))
