"""Top-level work-distribution decision process (paper Fig. 4 / Sec. 3.2).

The Scheduler receives execution requests from the Library layer and:

  1. on a **new (SCT, workload)** pair — derives a framework configuration
     ("Derive work distribution"): exact KB hit, or scattered-data
     interpolation over collected knowledge; the derived profile is
     persisted (the derivation populates the KB, acting as a cache);
  2. on a **recurrent** pair — checks whether the previous runs were
     unbalanced (lbt detector); if so, either *builds* an SCT profile from
     scratch (Algorithm 1 — only when explicitly enabled and none exists)
     or *adjusts* the current distribution with the adaptive binary search;
  3. dispatches: decomposes the data per the locality-aware plan into the
     per-slot partitions and hands the task group to the executor
     (work queues -> Task Launcher, paper Fig. 2).

The executor is pluggable — :class:`repro.core.executor.ThreadedExecutor`
(real partitioned runs on this host) and
:class:`repro.core.simulator.SimulatedExecutor` share the interface.

Recurrent-graph fast path
-------------------------
The paper's scheduler amortises partitioning decisions across recurrent
executions of the same compound computation.  Two layers implement that
here:

  * **whole-graph plan caching** (:class:`GraphPlanCache`) — a submitted
    :class:`~repro.core.graph.JobGraph` is keyed on its structural
    signature plus the input-array shapes; a hit replays the recorded
    per-node :class:`NodePlan` (profile, slots, shares, concrete
    partitioning), so every node dispatches **without re-entering the
    locked decide phase** (zero decide/plan lock acquisitions).  The
    observe phase still runs: KB ``best_time`` refinement and lbt
    updates apply to pre-planned runs, and an unbalance trigger or any
    device-health movement invalidates the graph level so the next
    submission re-plans per node.
  * **cross-request fusion** — with ``fusion_window > 0``, *identical*
    single-node graphs (same SCT shape signature, same options)
    admitted within the window are coalesced into one wider
    partitioning: their inputs are concatenated along each vector's
    partition dimension, one fused run executes (one decide phase, one
    dispatch, one merge), and each request's
    :class:`~repro.core.graph.GraphHandle` settles from a copied slice
    of the fused outputs.  Only SCTs whose kernels are oblivious to
    partition placement fuse (no SIZE/OFFSET traits, every output
    partitionable, no user merge functions, no host-side reductions),
    so fused results are bit-identical to independently-run requests —
    including under fault-injected repartition, which tiles lost unit
    ranges in domain order.

Failure semantics
-----------------
Device failure is a first-class scheduling signal, tracked by
:class:`~repro.core.faults.DeviceHealth`: every scheduled run records
per-device success/failure from the executor's fault records; a device
crossing the consecutive-failure threshold is *quarantined* — ``_slots``
and ``_per_slot_shares`` rebuild without it, degrading gracefully to
CPU-only or GPU-only execution — and after a probation interval it
re-enters with a small probe share, one clean run away from full
reinstatement.  Statistics of failed runs are *excluded* from
``LoadBalancer.observe`` and from KB ``best_time`` refinement, so fault
noise cannot corrupt learned profiles; a run whose retries are exhausted
surfaces as :class:`~repro.core.faults.ExecutionError` with the per-slot
fault history attached.
"""
from __future__ import annotations

import collections
import concurrent.futures as cf
import dataclasses
import math
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.autotuner import TunerParams, build_profile
from repro.core.decomposition import (ConcretePartitioning, DecompositionPlan,
                                      ExecutionSlot, build_plan)
from repro.core.distribution import Distribution
from repro.core.faults import DeviceHealth, ExecutionError
from repro.core.graph import (GraphDriver, GraphHandle, JobGraph,
                              _wrap_node_error)
from repro.core.knowledge_base import (KnowledgeBase, Origin, PlatformConfig,
                                       Profile)
from repro.core.load_balancer import ExecutionStats, LoadBalancer, class_times
from repro.core.platforms import AcceleratorPlatform, HostPlatform
from repro.core.skeletons import SCT
from repro.core.spec import Trait, Workload
from repro.core.telemetry import NULL_TELEMETRY, Telemetry


@dataclasses.dataclass
class ScheduledRun:
    """Outcome of one scheduled execution."""

    outputs: Dict[str, Any]
    stats: ExecutionStats
    profile: Profile
    action: str     # "exact" | "derived" | "built" | "adjusted" | "reused"
                    #   | "preplanned" | "fused"
    resident_handle: Optional[Any] = None   # slot-resident outputs, if kept
    node_plan: Optional["NodePlan"] = None  # the plan this run executed under

    def detach(self) -> "ScheduledRun":
        """Deep-copy the outputs out of the executor's reusable merge
        buffers, so they survive subsequent runs on the same executor
        (the documented output-aliasing footgun).  Returns ``self``."""
        self.outputs = {k: np.copy(v) if isinstance(v, np.ndarray) else v
                        for k, v in self.outputs.items()}
        return self


@dataclasses.dataclass(frozen=True)
class NodePlan:
    """Replayable outcome of the decide + plan phases for one node.

    Recorded on every dispatch; a :class:`GraphPlanCache` hit replays
    these verbatim through ``Scheduler.run``'s pre-planned fast path.
    Valid only while the device-health version it was recorded under
    still holds — a stale plan silently falls back to ordinary
    planning."""

    profile: Profile
    slots: Tuple[ExecutionSlot, ...]
    shares: Tuple[float, ...]
    part: ConcretePartitioning
    health_version: int


@dataclasses.dataclass(frozen=True)
class GraphPlan:
    """One whole-graph cache entry: node plans in topological order."""

    node_plans: Tuple[NodePlan, ...]
    health_version: int
    epoch: int                  # plan-cache epoch the plans were recorded in


@dataclasses.dataclass
class _FusionMember:
    """One request riding in a fusion batch (a single-node graph)."""

    arrays: Dict[str, Any]
    handle: GraphHandle
    node: str
    sct: SCT


class _FusionBatch:
    """One open fusion window: identical single-node requests
    accumulating until the window timer fires or ``fusion_max``
    members have joined."""

    def __init__(self, key: Tuple, options: Tuple):
        self.key = key
        self.options = options          # (deadline, retries, retry_backoff)
        self.members: List[_FusionMember] = []
        self.timer: Optional[threading.Timer] = None
        self.closed = False


class GraphPlanCache:
    """Plan / partitioning / graph-plan cache for recurrent dispatches.

    Three levels, mirroring the costs on the dispatch path:

      * decomposition plans, keyed by ``(sct_id, input shapes)`` — the
        expensive ``build_plan`` constraint derivation;
      * concrete partitionings, keyed by the full
        ``(sct_id, input shapes, slot signature, shares)`` tuple — the
        quantised largest-remainder allocation;
      * whole-graph plans (:class:`GraphPlan`), keyed by
        ``(JobGraph.signature(), input shapes/dtypes)`` — the complete
        topo-ordered decide+plan outcome of one clean graph execution,
        replayed on recurrent submissions so not a single node
        re-enters the locked decide phase.

    The slot signature covers device identity, class and per-kernel wgs,
    and the share vector is part of the key, so any slot-set or
    distribution change self-invalidates by missing.  ``invalidate`` is
    additionally called *explicitly* by the Scheduler whenever the
    device-health version moves (quarantine / probation / reinstatement)
    or a run adjusts the distribution (``adjusted`` / ``built``
    actions), so stale entries are dropped rather than merely bypassed;
    graph-level entries are dropped on the same signals (plus an lbt
    trigger observed on a pre-planned run), and each entry additionally
    carries the device-health version it was recorded under.

    Thread-safe: lookups and mutations serialise on an internal lock
    (plan construction itself runs outside it — it is pure).
    """

    def __init__(self, *, enabled: bool = True, capacity: int = 64):
        self.enabled = enabled
        self.capacity = capacity
        self.hits = 0
        self.misses = 0
        self.invalidations = 0
        self.graph_hits = 0
        self.graph_misses = 0
        self.telemetry: Telemetry = NULL_TELEMETRY
        self._lock = threading.Lock()
        self._plans: Dict[Tuple, DecompositionPlan] = {}
        self._parts: Dict[Tuple, ConcretePartitioning] = {}
        self._graphs: Dict[Tuple, GraphPlan] = {}

    # -- key components -----------------------------------------------------
    @staticmethod
    def shapes_sig(shapes: Dict[str, Tuple[int, ...]]) -> Tuple:
        return tuple(sorted((k, tuple(int(d) for d in v))
                            for k, v in shapes.items()))

    @staticmethod
    def slot_sig(slots: Sequence[ExecutionSlot]) -> Tuple:
        return tuple((s.device, s.device_type, tuple(sorted(s.wgs.items())))
                     for s in slots)

    @staticmethod
    def share_sig(shares: Sequence[float]) -> Tuple:
        return tuple(round(float(s), 12) for s in shares)

    # -- cache operations ----------------------------------------------------
    def partition(self, sct: SCT, shapes: Dict[str, Tuple[int, ...]],
                  slots: Sequence[ExecutionSlot], shares: Sequence[float]
                  ) -> Tuple[ConcretePartitioning, bool]:
        """Cached equivalent of ``build_plan(...).partition(...)``.

        Returns ``(partitioning, hit)``; with caching disabled this is
        exactly the uncached dispatch path.
        """
        if not self.enabled:
            return build_plan(sct, shapes).partition(slots, shares), False
        key = (sct.unique_id(), self.shapes_sig(shapes),
               self.slot_sig(slots), self.share_sig(shares))
        with self._lock:
            part = self._parts.get(key)
            if part is not None:
                self.hits += 1
                return part, True
            self.misses += 1
        plan = self.plan_for(sct, shapes)
        part = plan.partition(slots, shares)
        with self._lock:
            self._put(self._parts, key, part)
        return part, False

    def plan_for(self, sct: SCT,
                 shapes: Dict[str, Tuple[int, ...]]) -> DecompositionPlan:
        """Cached ``build_plan`` (no partitioning) — shared by the
        dispatch path and cross-request fusion's concatenated-input
        planning.  Does not touch the hit/miss counters."""
        if not self.enabled:
            return build_plan(sct, shapes)
        pkey = (sct.unique_id(), self.shapes_sig(shapes))
        with self._lock:
            plan = self._plans.get(pkey)
        if plan is None:
            plan = build_plan(sct, shapes)
            with self._lock:
                self._put(self._plans, pkey, plan)
        return plan

    # -- graph level ---------------------------------------------------------
    def graph_get(self, key: Tuple,
                  health_version: int) -> Optional[GraphPlan]:
        """Whole-graph lookup; drops (and misses on) entries recorded
        under a different device-health version."""
        if not self.enabled:
            return None
        with self._lock:
            gp = self._graphs.get(key)
            if gp is not None and gp.health_version != health_version:
                del self._graphs[key]
                gp = None
            if gp is not None:
                self.graph_hits += 1
            else:
                self.graph_misses += 1
            return gp

    def graph_put(self, key: Tuple, plan: GraphPlan) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._put(self._graphs, key, plan)

    def credit_graph_hit(self) -> None:
        """Count one pre-planned node dispatch as a plan-cache hit.

        Keeps ``hit_rate`` consistent with the per-run
        ``plan_cache_{hits,misses}_total`` metrics: every scheduled run
        increments exactly one of the two, whichever level served it."""
        with self._lock:
            self.hits += 1

    def _put(self, store: Dict, key: Tuple, value) -> None:
        if len(store) >= self.capacity:        # FIFO bound: drop the oldest
            store.pop(next(iter(store)))
        store[key] = value

    def invalidate(self, reason: str = "") -> None:
        """Drop every cached plan/partitioning/graph plan (slot set or
        shares moved)."""
        with self._lock:
            self.invalidations += 1
            self._plans.clear()
            self._parts.clear()
            had_graphs = bool(self._graphs)
            self._graphs.clear()
        self.telemetry.metrics.counter("plan_cache_invalidations_total").inc()
        if had_graphs:
            self.telemetry.metrics.counter(
                "graph_plan_cache_invalidations_total").inc()
        self.telemetry.events.emit("plan_cache.invalidated", reason=reason)

    def invalidate_graphs(self, reason: str = "") -> None:
        """Drop the graph level only (e.g. lbt trigger: the recorded
        distribution is stale, but per-node plans keyed on explicit
        shares remain valid)."""
        with self._lock:
            if not self._graphs:
                return
            self._graphs.clear()
        self.telemetry.metrics.counter(
            "graph_plan_cache_invalidations_total").inc()
        self.telemetry.events.emit("plan_cache.graphs_invalidated",
                                   reason=reason)

    @property
    def epoch(self) -> int:
        """Monotone invalidation epoch: a recorded plan is only stored
        if the epoch did not move while its graph was in flight."""
        return self.invalidations

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def counters(self) -> Dict[str, float]:
        return {"hits": self.hits, "misses": self.misses,
                "invalidations": self.invalidations,
                "hit_rate": self.hit_rate,
                "graph_hits": self.graph_hits,
                "graph_misses": self.graph_misses}


#: Backwards-compatible alias — the two-level cache grew a graph level.
PlanCache = GraphPlanCache


class Scheduler:
    def __init__(self, *, host: HostPlatform, accel: AcceleratorPlatform,
                 executor, kb: Optional[KnowledgeBase] = None,
                 balancer: Optional[LoadBalancer] = None,
                 allow_profile_build: bool = False,
                 tuner_params: TunerParams = TunerParams(),
                 default_share_a: float = 0.8,
                 health: Optional[DeviceHealth] = None,
                 plan_cache: bool = True,
                 telemetry: Optional[Telemetry] = None,
                 max_inflight: int = 4,
                 graph_workers: int = 8,
                 fusion_window: float = 0.0,
                 fusion_max: int = 8):
        self.host = host
        self.accel = accel
        self.executor = executor
        self.kb = kb if kb is not None else KnowledgeBase()
        self.balancer = balancer if balancer is not None else LoadBalancer()
        self.allow_profile_build = allow_profile_build
        self.tuner_params = tuner_params
        self.default_share_a = default_share_a
        self.health = health if health is not None else DeviceHealth()
        self.plan_cache = PlanCache(enabled=plan_cache)
        self._health_seen = self.health.version
        self._last_key: Optional[Tuple[str, str]] = None
        self._current: Optional[Profile] = None
        self._last_slots: List[ExecutionSlot] = []
        self._last_class_times: Tuple[float, float] = (0.0, 0.0)
        self._counts = {"runs": 0, "failed_runs": 0, "retries": 0,
                        "resident_handoffs": 0, "graphs": 0,
                        "decide_locks": 0, "plan_locks": 0,
                        "fused_requests": 0, "fused_batches": 0}
        # decision/observation state is shared by concurrent graph nodes;
        # RLock because the autotuner evaluator re-enters _dispatch
        self._lock = threading.RLock()
        # the plan phase has its own lock: concurrent nodes planning
        # never queue behind another node's decide/observe phase, and a
        # pre-planned dispatch acquires neither lock
        self._plan_lock = threading.Lock()
        # graph admission: FIFO queue, at most max_inflight graphs live
        self.max_inflight = max_inflight
        self.graph_workers = graph_workers
        self._graph_lock = threading.Lock()
        self._admission: "collections.deque[GraphDriver]" = \
            collections.deque()
        self._running: set = set()
        self._graph_seq = 0
        self._graph_pool_obj: Optional[cf.ThreadPoolExecutor] = None
        self._virtual_busy: Dict[str, float] = {}   # virtual-clock queues
        # cross-request fusion (admission-side; off unless a window is set)
        self.fusion_window = float(fusion_window)
        self.fusion_max = int(fusion_max)
        self._fusion_lock = threading.Lock()
        self._fusion_batches: Dict[Tuple, _FusionBatch] = {}
        self._fusion_sct_ok: Dict[str, bool] = {}   # static eligibility memo
        self._closed = False
        self.telemetry = NULL_TELEMETRY
        self.attach_telemetry(telemetry or NULL_TELEMETRY)

    def attach_telemetry(self, telemetry: Telemetry) -> None:
        """Share one telemetry bundle across the whole pipeline.

        Propagated to the plan cache, the executor, the device-health
        tracker and the load balancer, so spans, metrics and events
        from every layer land in a single trace/registry."""
        self.telemetry = telemetry
        self.plan_cache.telemetry = telemetry
        self.health.telemetry = telemetry
        self.balancer.telemetry = telemetry
        if hasattr(self.executor, "telemetry"):
            self.executor.telemetry = telemetry

    # ------------------------------------------------------------------
    def run(self, sct: SCT, arrays: Dict[str, Any],
            workload: Optional[Workload] = None, *,
            _resident=None, _keep_resident: bool = False,
            _plan: Optional[NodePlan] = None) -> ScheduledRun:
        """One scheduled execution.  Thread-safe: the decision and
        observation phases serialise on the scheduler lock; the execute
        phase runs unlocked, so independent graph nodes overlap on the
        executor's per-device work queues.

        ``_plan`` (internal — a :class:`NodePlan` from a
        :class:`GraphPlanCache` hit) replays a recorded decision
        verbatim: both the locked decide phase and the locked plan
        phase are skipped entirely.  A stale plan (the device-health
        version moved since it was recorded) falls back to ordinary
        planning.  The observation phase runs either way, so KB
        ``best_time`` refinement and lbt updates see pre-planned runs
        too."""
        plan: Optional[NodePlan] = None
        if (_plan is not None and self.plan_cache.enabled
                and _plan.health_version == self.health.version):
            plan = _plan
        key: Optional[Tuple[str, str]] = None
        if plan is None:
            shapes = _resident.shapes() if _resident is not None else None
            workload = workload or infer_workload(sct, arrays, shapes=shapes)
            key = (sct.unique_id(), workload.key())

        tel = self.telemetry
        wl = str(workload.key()) if workload is not None else "preplanned"
        with tel.tracer.span("run", sct=sct.unique_id(),
                             workload=wl) as run_span:
            if plan is None:
                with self._lock:        # decision phase (Fig. 4)
                    self._counts["decide_locks"] += 1
                    if key != self._last_key or self._current is None:
                        profile, action = self._derive(sct, workload)
                    else:
                        profile, action = self._recurrent(sct, workload)
                    self._last_key, self._current = key, profile
                    run_span.note(action=action)
                    tel.metrics.counter("scheduler_actions_total",
                                        action=action).inc()

                    # explicit plan-cache invalidation: distribution
                    # adjusted, profile rebuilt, or the device-health state
                    # (quarantine / probation / reinstatement) moved since
                    # the entries were created
                    if action in ("adjusted", "built"):
                        self.plan_cache.invalidate("share adjustment")
                    if self.health.version != self._health_seen:
                        self.plan_cache.invalidate("device-health change")
                        self._health_seen = self.health.version

                    self.health.tick()
            else:
                # pre-planned fast path: zero decide/plan lock round trips
                profile, action = plan.profile, "preplanned"
                self.plan_cache.credit_graph_hit()
                run_span.note(action=action)
                tel.metrics.counter("scheduler_actions_total",
                                    action=action).inc()
                self.health.tick()      # DeviceHealth has its own lock
            try:
                outputs, stats, slots, resident_handle, node_plan = \
                    self._dispatch(
                        sct, arrays, profile, resident=_resident,
                        keep_resident=_keep_resident, plan=plan)
            except ExecutionError as e:
                # terminal failure: still feed the health tracker, so repeat
                # offenders get quarantined even when no run ever completes
                # — and never touch the balancer / KB / _last_slots, so a
                # failed run cannot pollute learned state
                with self._lock:
                    for base in {r.device_base for r in e.records}:
                        self.health.record_failure(base)
                    self._counts["runs"] += 1
                    self._counts["failed_runs"] += 1
                tel.metrics.counter("runs_total", status="error").inc()
                tel.events.emit("run.error", level="error",
                                message=str(e), sct=sct.unique_id(),
                                attempts=e.attempts)
                raise
            with self._lock:        # observation phase (Monitor)
                self._last_slots = list(slots)
                self._observe_health(stats)
                self._record_run_metrics(sct, stats, slots)

                # update detector; persist best-known configurations.
                # Failed runs are excluded — their times mix real compute
                # with retry noise and would corrupt the lbt detector and
                # KB profiles.
                if stats.ok:
                    trigger = self.balancer.observe(stats)
                    if not trigger:
                        self.balancer.balanced_again()
                    else:
                        # unbalance detected: recorded whole-graph plans
                        # embed the now-suspect distribution — drop them
                        # so the next submission re-plans per node
                        self.plan_cache.invalidate_graphs("lbt trigger")
                    self._last_class_times = (stats.time_a, stats.time_b)
                    if stats.total < profile.best_time:
                        profile = dataclasses.replace(profile,
                                                      best_time=stats.total)
                        self.kb.store(profile)
                        if key is not None and self._last_key == key:
                            self._current = profile
            return ScheduledRun(outputs=outputs, stats=stats,
                                profile=profile, action=action,
                                resident_handle=resident_handle,
                                node_plan=node_plan)

    def _record_run_metrics(self, sct: SCT, stats: ExecutionStats,
                            slots: Sequence[ExecutionSlot]) -> None:
        """Fold one completed run into counters / metrics / events."""
        tel = self.telemetry
        self._counts["runs"] += 1
        self._counts["retries"] += stats.retries
        if not stats.ok:
            self._counts["failed_runs"] += 1
        if stats.resident:
            self._counts["resident_handoffs"] += 1
        tel.metrics.counter("runs_total",
                            status="ok" if stats.ok else "faulted").inc()
        if stats.retries:
            tel.metrics.counter("retries_total").inc(stats.retries)
            tel.metrics.counter("repartitions_total").inc(stats.retries)
        tel.metrics.counter(
            "plan_cache_hits_total" if stats.plan_cache_hit
            else "plan_cache_misses_total").inc()
        if stats.resident:
            tel.metrics.counter("resident_handoffs_total").inc()
        tel.metrics.counter("merge_bytes_total").inc(stats.merge_bytes)
        tel.metrics.histogram("class_makespan_seconds",
                              cls="a").observe(stats.time_a)
        tel.metrics.histogram("class_makespan_seconds",
                              cls="b").observe(stats.time_b)
        tel.metrics.histogram("overhead_seconds").observe(
            stats.overhead_seconds)
        for slot, t in zip(slots, stats.times):
            tel.metrics.counter("device_busy_seconds_total",
                                device=slot.device.split("/")[0]).inc(t)

    def counters(self) -> Dict[str, float]:
        """One namespaced counter dict across the whole pipeline.

        Folds the plan-cache numbers together with scheduler run/retry
        counts, executor pool reuse and resident handoffs (re-exported
        through :meth:`Session.counters`)."""
        out: Dict[str, float] = {
            f"plan_cache.{k}": v
            for k, v in self.plan_cache.counters().items()}
        with self._lock:
            for k, v in self._counts.items():
                out[f"scheduler.{k}"] = v
        ex = self.executor
        out["executor.pools_created"] = getattr(ex, "pools_created", 0)
        out["executor.pool_reuses"] = getattr(ex, "pool_reuses", 0)
        out["health.quarantined"] = len(self.health.quarantined())
        out["balancer.balance_ops"] = self.balancer.balance_ops
        out["balancer.unbalanced_runs"] = self.balancer.unbalanced_runs
        return out

    def run_chain(self, scts: Sequence[SCT], arrays: Dict[str, Any]
                  ) -> List[ScheduledRun]:
        """Run a compound SCT chain with partitioned residency.

        Each step's slot-local outputs are handed straight to the next
        step (``ResidentPartition``), skipping the merge→re-split round
        trip as long as consecutive steps share the domain decomposition;
        on any mismatch — or on an executor without residency support —
        the handle materialises and the step runs on the ordinary merged
        path.  The final step always merges, so the last
        :class:`ScheduledRun` carries the chain's outputs.  Intermediate
        results that stayed resident are *not* merged back into the
        caller's environment (that is the optimisation).
        """
        supports = bool(getattr(self.executor, "supports_residency", False))
        env = dict(arrays)
        resident = None
        runs: List[ScheduledRun] = []
        for i, sct in enumerate(scts):
            keep = supports and i < len(scts) - 1
            r = self.run(sct, env, _resident=resident,
                         _keep_resident=keep)
            resident = r.resident_handle if keep else None
            if r.outputs:               # merged (final or fallback) results
                env.update(r.outputs)
            runs.append(r)
        return runs

    # -- graph pipeline -------------------------------------------------------
    def submit(self, graph: JobGraph, arrays: Dict[str, Any], *,
               deadline: Optional[float] = None, retries: int = 0,
               retry_backoff: float = 0.05) -> GraphHandle:
        """Admit one JobGraph for execution; returns its handle.

        On the threaded executor the graph enters a FIFO admission queue
        (at most ``max_inflight`` graphs execute at once) and its
        dependency-free nodes start on the node pool immediately after
        admission; nodes on disjoint device slots genuinely overlap.  On
        a virtual-clock executor (``SimulatedExecutor``) the graph runs
        inline, deterministically, on the simulated timeline — the
        handle is already settled when this returns.

        ``deadline`` / ``retries`` / ``retry_backoff`` apply per node,
        with the whole-graph ``deadline`` budget shared across nodes.

        Recurrent submissions take two fast paths: a
        :class:`GraphPlanCache` hit pre-plans every node up front (zero
        decide/plan lock acquisitions while the graph runs), and —
        with ``fusion_window > 0`` — identical single-node graphs
        admitted within the window coalesce into one fused run (module
        docstring).  Both settle the returned handle exactly as the
        ordinary path does."""
        graph.validate()
        tel = self.telemetry
        virtual = bool(getattr(self.executor, "virtual_clock", False))
        if not virtual:
            fused = self._try_fuse(graph, arrays, deadline=deadline,
                                   retries=retries,
                                   retry_backoff=retry_backoff)
            if fused is not None:
                return fused
        with self._graph_lock:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            self._graph_seq += 1
            rid = f"g{self._graph_seq}"
        handle = GraphHandle(graph, rid)
        preplanned, plan_key, plan_epoch = \
            self._graph_plan_lookup(graph, arrays)
        driver = GraphDriver(self, handle, arrays, deadline=deadline,
                             retries=retries, retry_backoff=retry_backoff,
                             preplanned=preplanned, plan_key=plan_key,
                             plan_epoch=plan_epoch)
        with self._lock:
            self._counts["graphs"] += 1
        tel.metrics.counter("graph_nodes_total").inc(len(graph))
        tel.events.emit("graph.submitted", request=rid, nodes=len(graph))
        if virtual:
            driver.run_virtual()
            return handle
        with self._graph_lock:
            self._admission.append(driver)
            started = self._pump_locked()
        for d in started:
            d.start()
        return handle

    # -- whole-graph plan cache ----------------------------------------------
    def _graph_plan_lookup(self, graph: JobGraph, arrays: Dict[str, Any]
                           ) -> Tuple[Optional[List[NodePlan]],
                                      Optional[Tuple], int]:
        """(pre-planned node plans, miss key to record under, epoch)."""
        pc = self.plan_cache
        if not pc.enabled:
            return None, None, 0
        key = (graph.signature(), _array_sig(arrays))
        gp = pc.graph_get(key, self.health.version)
        tel = self.telemetry
        if gp is not None:
            tel.metrics.counter("graph_plan_cache_hits_total").inc()
            tel.events.emit("graph_plan_cache.hit", nodes=len(graph))
            return list(gp.node_plans), None, gp.epoch
        tel.metrics.counter("graph_plan_cache_misses_total").inc()
        return None, key, pc.epoch

    def _graph_plan_record(self, driver: GraphDriver) -> None:
        """Record a cleanly completed graph's per-node plans (miss path;
        called by ``GraphDriver._finalize``).

        Skipped when anything moved while the graph was in flight — a
        plan-cache invalidation (distribution adjustment), a
        device-health transition, or any node that faulted/retried:
        recording those would replay a decision the scheduler has
        already walked away from."""
        key = getattr(driver, "plan_key", None)
        pc = self.plan_cache
        if key is None or not pc.enabled or pc.epoch != driver.plan_epoch:
            return
        hv = self.health.version
        plans: List[NodePlan] = []
        for name in driver.graph.topo_order():
            run = driver.handle.runs.get(name)
            np_ = getattr(run, "node_plan", None)
            if np_ is None or not run.stats.ok or run.stats.retries:
                return
            if np_.health_version != hv:
                return
            plans.append(np_)
        pc.graph_put(key, GraphPlan(node_plans=tuple(plans),
                                    health_version=hv,
                                    epoch=driver.plan_epoch))

    # -- cross-request fusion ------------------------------------------------
    def _try_fuse(self, graph: JobGraph, arrays: Dict[str, Any], *,
                  deadline: Optional[float], retries: int,
                  retry_backoff: float) -> Optional[GraphHandle]:
        """Admission-side fusion of identical single-node graphs.

        Returns a handle when the request joined a fusion batch, else
        ``None`` (ordinary admission).  The handle settles when its
        batch flushes — after ``fusion_window`` seconds, or immediately
        once ``fusion_max`` members have joined."""
        if self.fusion_window <= 0 or len(graph) != 1:
            return None
        node = graph.nodes[0]
        options = (deadline, int(retries), float(retry_backoff))
        key = self._fusion_key(node.sct, arrays, options)
        if key is None:
            return None
        with self._graph_lock:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            self._graph_seq += 1
            rid = f"g{self._graph_seq}"
        handle = GraphHandle(graph, rid)
        with self._lock:
            self._counts["graphs"] += 1
        tel = self.telemetry
        tel.metrics.counter("graph_nodes_total").inc(1)
        tel.events.emit("graph.submitted", request=rid, nodes=1)
        flush: Optional[_FusionBatch] = None
        with self._fusion_lock:
            batch = self._fusion_batches.get(key)
            if batch is None:
                batch = _FusionBatch(key, options)
                self._fusion_batches[key] = batch
                timer = threading.Timer(self.fusion_window,
                                        self._flush_batch, args=(batch,))
                timer.daemon = True
                batch.timer = timer
                timer.start()
            batch.members.append(_FusionMember(arrays=dict(arrays),
                                               handle=handle,
                                               node=node.name,
                                               sct=node.sct))
            if len(batch.members) >= self.fusion_max:
                flush = self._close_batch_locked(batch)
        if flush is not None:
            self._enqueue_fused(flush)
        return handle

    def _fusion_key(self, sct: SCT, arrays: Dict[str, Any],
                    options: Tuple) -> Optional[Tuple]:
        """Fusion identity of a request, or ``None`` when it must not
        fuse.  Covers the SCT (structural id), every vector's
        shape+dtype, every scalar's *value* (scalars broadcast across
        the fused domain, so differing values must not coalesce) and
        the request options."""
        sid = sct.unique_id()
        ok = self._fusion_sct_ok.get(sid)
        if ok is None:
            ok = self._fusion_eligible(sct)
            self._fusion_sct_ok[sid] = ok
        if not ok:
            return None
        names = set()
        parts: List[Tuple] = []
        for a in sct.free_inputs():
            names.add(a.name)
            v = arrays.get(a.name)
            if a.kind == "scalar":
                try:
                    parts.append((a.name, "s", float(v)))
                except (TypeError, ValueError):
                    return None
                continue
            if not a.partitionable:
                return None     # COPY input: replicated, values unproven
            if v is None or getattr(v, "ndim", 0) < 1:
                return None
            parts.append((a.name, "v",
                          tuple(int(d) for d in v.shape),
                          str(getattr(v, "dtype", ""))))
        if any(k not in names for k in arrays):
            return None         # undeclared extra inputs: safe path
        return (sid, tuple(parts), options)

    def _fusion_eligible(self, sct: SCT) -> bool:
        """Static fusibility of an SCT: every kernel oblivious to
        partition placement, every output partitionable.

        SIZE/OFFSET-trait scalars see different values under a fused
        (wider) domain; non-PARTITION outputs, host-side reductions and
        user merge functions combine globally (possibly non-linearly).
        Any of these would break the output-slicing guarantee, so such
        SCTs never fuse."""
        for spec in sct.kernel_specs():
            for a in spec.inputs:
                if a.trait is not Trait.NONE:
                    return False
            for a in spec.outputs:
                if not a.partitionable:
                    return False
        from repro.core.skeletons import MapReduce
        stack: List[SCT] = [sct]
        while stack:
            n = stack.pop()
            if isinstance(n, MapReduce) and n.host_side_reduction:
                return False
            stack.extend(n.children())
        merges = getattr(self.executor, "merges", None) or {}
        if merges:
            from repro.core.executor import _produced_names
            if any(name in merges for name in _produced_names(sct)):
                return False
        return True

    def _close_batch_locked(self, batch: _FusionBatch) -> _FusionBatch:
        """Caller holds ``_fusion_lock``."""
        batch.closed = True
        if batch.timer is not None:
            batch.timer.cancel()
        self._fusion_batches.pop(batch.key, None)
        return batch

    def _flush_batch(self, batch: _FusionBatch) -> None:
        """Window expired (timer thread): move the batch to admission."""
        with self._fusion_lock:
            if batch.closed:
                return
            self._close_batch_locked(batch)
        self._enqueue_fused(batch)

    def _flush_open_batches(self) -> None:
        """Flush every open batch immediately (drain path)."""
        with self._fusion_lock:
            open_ = [b for b in self._fusion_batches.values()
                     if not b.closed]
            for b in open_:
                self._close_batch_locked(b)
        for b in open_:
            self._enqueue_fused(b)

    def _enqueue_fused(self, batch: _FusionBatch) -> None:
        driver = _FusedDriver(self, batch)
        with self._graph_lock:
            self._admission.append(driver)
            started = self._pump_locked()
        for d in started:
            d.start()

    def _run_fused(self, batch: _FusionBatch) -> None:
        """Execute one flushed batch: one fused run (one decide phase,
        one dispatch, one merge), each member settled from a copied
        slice of the fused outputs.  Falls back to per-member runs when
        the batch has a single member or concatenation fails."""
        members = batch.members
        deadline, retries, backoff = batch.options
        tel = self.telemetry
        epoch = time.perf_counter()

        def now_us() -> float:
            return (time.perf_counter() - epoch) * 1e6

        fused = self._fuse_arrays(members) if len(members) > 1 else None
        if fused is None:
            for m in members:
                start = now_us()
                try:
                    run = self._request_with_retries(
                        m.sct, m.arrays, deadline=deadline,
                        retries=retries, backoff=backoff)
                except BaseException as e:
                    self._settle_member(m, error=e, span=(start, now_us()))
                else:
                    self._settle_member(m, run=run, span=(start, now_us()))
            return
        fused_arrays, slicers = fused
        with self._lock:
            self._counts["fused_batches"] += 1
            self._counts["fused_requests"] += len(members)
        tel.metrics.counter("fused_batches_total").inc()
        tel.metrics.counter("fused_requests_total").inc(len(members))
        tel.events.emit("graph.fused", batch=len(members),
                        requests=[m.handle.request_id for m in members])
        start = now_us()
        try:
            run = self._request_with_retries(
                members[0].sct, fused_arrays, deadline=deadline,
                retries=retries, backoff=backoff)
        except BaseException as e:
            end = now_us()
            for m in members:
                self._settle_member(m, error=e, span=(start, end))
            return
        end = now_us()
        for i, m in enumerate(members):
            outs: Dict[str, Any] = {}
            for oname, arr in run.outputs.items():
                sl = slicers.get(oname)
                if sl is None or not isinstance(arr, np.ndarray):
                    outs[oname] = arr
                    continue
                axis, per = sl
                idx = [slice(None)] * arr.ndim
                idx[axis] = slice(i * per, (i + 1) * per)
                outs[oname] = np.copy(arr[tuple(idx)])
            sub = ScheduledRun(outputs=outs, stats=run.stats,
                               profile=run.profile, action="fused")
            self._settle_member(m, run=sub, span=(start, end))

    def _fuse_arrays(self, members: List[_FusionMember]
                     ) -> Optional[Tuple[Dict[str, Any],
                                         Dict[str, Tuple[int, int]]]]:
        """Concatenate member inputs along each vector's partition dim.

        Returns ``(fused arrays, output slicers)`` or ``None`` when a
        plan constraint fails (the caller falls back to individual
        runs).  ``slicers[name] = (axis, extent-per-member)`` for every
        produced output; eligibility already guaranteed every output
        partitionable, so slicing the fused result along its partition
        dim reproduces each member's independent output."""
        sct = members[0].sct
        first = members[0].arrays
        shapes = {k: tuple(getattr(v, "shape", ()))
                  for k, v in first.items()}
        try:
            plan = self.plan_cache.plan_for(sct, shapes)
        except Exception:
            return None
        units = plan.domain_units
        if units <= 0:
            return None
        fused: Dict[str, Any] = {}
        for a in sct.free_inputs():
            if a.kind == "scalar":
                if a.name in first:
                    fused[a.name] = first[a.name]
                continue
            vp = plan.vectors.get(a.name)
            if vp is None or vp.copy:
                return None
            try:
                fused[a.name] = np.concatenate(
                    [np.asarray(m.arrays[a.name]) for m in members],
                    axis=vp.partition_dim)
            except Exception:
                return None
        from repro.core.executor import _produced_names, output_spec
        slicers: Dict[str, Tuple[int, int]] = {}
        for oname in _produced_names(sct):
            spec = output_spec(sct, oname)
            if spec is None or not spec.partitionable:
                return None     # unreachable: eligibility filtered these
            slicers[oname] = (spec.partition_dim, units * spec.epu)
        return fused, slicers

    def _request_with_retries(self, sct: SCT, arrays: Dict[str, Any], *,
                              deadline: Optional[float], retries: int,
                              backoff: float) -> ScheduledRun:
        """Per-request retry loop around :meth:`run` (fused path) —
        same deadline-capped exponential backoff as ``GraphDriver``."""
        t0 = time.monotonic()
        last: Optional[ExecutionError] = None
        for k in range(retries + 1):
            if deadline is not None and time.monotonic() - t0 > deadline:
                raise ExecutionError(
                    f"request deadline {deadline}s exceeded after "
                    f"{k} attempts", getattr(last, "records", []), k)
            try:
                return self.run(sct, arrays)
            except ExecutionError as e:
                last = e
                if k == retries:
                    raise
                pause = backoff * (2 ** k)
                if deadline is not None:
                    remaining = deadline - (time.monotonic() - t0)
                    if remaining <= 0:
                        raise ExecutionError(
                            f"request deadline {deadline}s exceeded after "
                            f"{k + 1} attempts", e.records, k + 1)
                    pause = min(pause, remaining)
                if pause > 0:
                    time.sleep(pause)
        raise last  # pragma: no cover — loop always returns or raises

    def _settle_member(self, member: _FusionMember, *,
                       run: Optional[ScheduledRun] = None,
                       error: Optional[BaseException] = None,
                       span: Tuple[float, float] = (0.0, 0.0)) -> None:
        """Settle one fused request's (single-node) handle."""
        handle, name = member.handle, member.node
        tel = self.telemetry
        if error is not None:
            with handle._lock:
                handle._state[name] = "failed"
                handle._spans[name] = span
            tel.metrics.counter("graph_nodes_failed_total").inc()
            tel.metrics.counter("graphs_total", status="error").inc()
            tel.events.emit("graph.node_failed", level="error",
                            request=handle.request_id, node=name,
                            message=str(error))
            handle._finish(_wrap_node_error(name, error))
            return
        handle.runs[name] = run
        with handle._lock:
            handle._state[name] = "done"
            handle._spans[name] = span
        tel.metrics.counter("graphs_total", status="ok").inc()
        tel.events.emit("graph.done", request=handle.request_id, failed=0)
        handle._finish(None)

    def _pump_locked(self) -> List[GraphDriver]:
        """Admit queued graphs up to ``max_inflight``; caller holds
        ``_graph_lock`` and must ``start()`` the returned drivers."""
        started: List[GraphDriver] = []
        while self._admission and len(self._running) < self.max_inflight:
            d = self._admission.popleft()
            self._running.add(d)
            started.append(d)
        return started

    def _graph_done(self, driver: GraphDriver) -> None:
        """Completion callback from a GraphDriver: admit the next graph."""
        with self._graph_lock:
            self._running.discard(driver)
            started = self._pump_locked()
        for d in started:
            d.start()

    def _graph_pool(self) -> cf.ThreadPoolExecutor:
        """Lazily created node pool shared by every admitted graph."""
        with self._graph_lock:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            if self._graph_pool_obj is None:
                self._graph_pool_obj = cf.ThreadPoolExecutor(
                    max_workers=self.graph_workers,
                    thread_name_prefix="graph-node")
            return self._graph_pool_obj

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted graph settles (or ``timeout``
        seconds elapse); returns True when fully drained.  Open fusion
        batches flush immediately rather than waiting out their
        window."""
        t0 = time.monotonic()
        while True:
            self._flush_open_batches()
            with self._graph_lock:
                live = list(self._running) + list(self._admission)
            if not live:
                return True
            if timeout is not None and time.monotonic() - t0 > timeout:
                return False
            live[0].handle.wait(0.05)

    def close(self) -> None:
        """Drain in-flight graphs, stop admission, release the node pool
        and the executor's resources.  Idempotent."""
        self.drain()
        with self._graph_lock:
            self._closed = True
            pool, self._graph_pool_obj = self._graph_pool_obj, None
        if pool is not None:
            pool.shutdown(wait=True)
        close = getattr(self.executor, "close", None)
        if close is not None:
            close()

    def _observe_health(self, stats) -> None:
        """Feed per-device success/failure of one run into the tracker."""
        failed = {r.device_base for r in stats.failures}
        participated = {s.device.split("/")[0] for s in self._last_slots}
        for base in participated - failed:
            self.health.record_success(base)
        for base in failed:
            self.health.record_failure(base)

    # ------------------------------------------------------------------
    def _derive(self, sct: SCT, workload: Workload) -> Tuple[Profile, str]:
        exact = self.kb.exact(sct.unique_id(), workload)
        if exact is not None:
            return exact, "exact"
        derived = self.kb.derive(sct.unique_id(), workload)
        if derived is not None:
            self.kb.store(derived)
            return derived, "derived"
        # empty KB: assume-good default, to be refined online (paper: the KB
        # is assumed sufficient; adjustments correct over-optimism)
        p = Profile(sct_id=sct.unique_id(), workload=workload,
                    share_a=self.default_share_a, config=PlatformConfig(),
                    best_time=math.inf, origin=Origin.DERIVED)
        self.kb.store(p)
        return p, "derived"

    def _recurrent(self, sct: SCT, workload: Workload) -> Tuple[Profile, str]:
        assert self._current is not None
        unbalanced = self.balancer.lbt >= self.balancer.trigger
        if not unbalanced:
            return self._current, "reused"
        have_built = (self._current.origin is Origin.BUILT)
        if self.allow_profile_build and not have_built:
            result = build_profile(
                sct.unique_id(), workload, host=self.host, accel=self.accel,
                evaluate=self._make_evaluator(sct, workload),
                params=self.tuner_params, kb=self.kb, sct=sct)
            self.balancer.reset_search()
            self.balancer.lbt = 0.0
            return result.profile, "built"
        # Adjust workload distribution (adaptive binary search) from the
        # last observed per-class makespans (scheduler-owned state: the
        # executor's last_* fields are not stable under concurrent nodes)
        last = self._last_class_times
        cur = Distribution(a=self._current.share_a, b=1 - self._current.share_a)
        new = self.balancer.adjust(cur, last[0], last[1])
        adjusted = dataclasses.replace(self._current, share_a=new.a,
                                       best_time=math.inf)
        return adjusted, "adjusted"

    # ------------------------------------------------------------------
    def _dispatch(self, sct: SCT, arrays: Dict[str, Any], profile: Profile,
                  *, resident=None, keep_resident: bool = False,
                  plan: Optional[NodePlan] = None
                  ) -> Tuple[Dict[str, Any], ExecutionStats,
                             List[ExecutionSlot], Any, NodePlan]:
        """Plan + execute one run; returns (outputs, stats, slots,
        resident handle, node plan).  The plan phase (slot generation,
        plan cache) serialises on the dedicated plan lock — not the
        decide/observe lock, so a node planning never queues behind
        another node's observation; execution does not lock at all.  A
        pre-resolved ``plan`` skips the phase (and the lock) entirely."""
        t0 = time.perf_counter()
        if plan is not None:
            slots, part = list(plan.slots), plan.part
            cache_hit = True
            node_plan = plan
        else:
            with self._plan_lock:
                self._counts["plan_locks"] += 1
                with self.telemetry.tracer.span("plan") as plan_span:
                    shapes = {k: tuple(getattr(v, "shape", ()))
                              for k, v in arrays.items()}
                    if resident is not None:
                        # slot-resident vectors are inputs too: plan over
                        # their global (merged) shapes without
                        # materialising them
                        shapes = {**resident.shapes(), **shapes}
                    slots = self._slots(profile)
                    shares = self._per_slot_shares(profile, slots)
                    part, cache_hit = self.plan_cache.partition(sct, shapes,
                                                                slots, shares)
                    plan_span.note(cache_hit=cache_hit, slots=len(slots))
            node_plan = NodePlan(profile=profile, slots=tuple(slots),
                                 shares=tuple(float(s) for s in shares),
                                 part=part,
                                 health_version=self.health.version)
        plan_seconds = time.perf_counter() - t0

        kwargs: Dict[str, Any] = {}
        if getattr(self.executor, "supports_residency", False):
            kwargs = {"resident": resident, "keep_resident": keep_resident}
        execute_result = getattr(self.executor, "execute_result", None)
        if execute_result is not None:
            # per-call result object: safe under concurrent graph nodes
            res = execute_result(sct, part, arrays, profile, **kwargs)
            outputs, times = res.outputs, res.times
            failures, retries = res.failures, res.retries
            timing = dict(res.timing or {})
            merge_bytes = res.merge_bytes
            resident_out = res.resident
        else:
            # legacy duck-typed executor: observe through last_* fields
            outputs, times = self.executor.execute(sct, part, arrays,
                                                   profile, **kwargs)
            failures = list(getattr(self.executor, "last_failures", []))
            retries = int(getattr(self.executor, "last_retries", 0))
            timing = dict(getattr(self.executor, "last_timing", {}) or {})
            merge_bytes = int(getattr(self.executor, "last_merge_bytes", 0))
            resident_out = getattr(self.executor, "last_resident", None)
        n_a = sum(1 for s in slots if s.device_type != "cpu")
        ta, tb = class_times(times, n_a)
        stats = ExecutionStats(
            times=list(times), share_a=profile.share_a, time_a=ta, time_b=tb,
            failures=failures,
            retries=retries,
            plan_seconds=plan_seconds,
            pool_seconds=float(timing.get("pool", 0.0)),
            dispatch_seconds=float(timing.get("dispatch", 0.0)),
            compute_seconds=float(timing.get("compute", 0.0)),
            merge_seconds=float(timing.get("merge", 0.0)),
            merge_bytes=merge_bytes,
            plan_cache_hit=cache_hit,
            resident=resident_out is not None)
        return outputs, stats, list(slots), resident_out, node_plan

    def _usable_accel_devices(self):
        return [d for d in self.accel.devices if self.health.usable(d.name)]

    def _slots(self, profile: Profile) -> List[ExecutionSlot]:
        """Accelerator slots first (class a), then host fission slots.

        Quarantined devices are excluded — the run degrades gracefully to
        CPU-only or GPU-only; a device due for probation re-enters here
        (with a probe-sized share, see :meth:`_per_slot_shares`).
        """
        self.host.configure(profile.config.fission_level)
        self.accel.configure(profile.config.overlap)
        slots: List[ExecutionSlot] = []
        for d in self._usable_accel_devices():
            for o in range(self.accel.overlap):
                slots.append(ExecutionSlot(device=f"{d.name}/q{o}",
                                           device_type=d.kind,
                                           wgs=dict(profile.config.wgs)))
        if self.health.usable(self.host.device.name):
            for i in range(self.host.parallelism):
                slots.append(ExecutionSlot(
                    device=f"{self.host.device.name}/f{i}",
                    device_type="cpu", wgs=dict(profile.config.wgs)))
        if not slots:
            raise ExecutionError(
                "all devices quarantined: no execution slots available "
                f"(quarantined: {sorted(self.health.quarantined())})")
        return slots

    def _per_slot_shares(self, profile: Profile,
                         slots: Sequence[ExecutionSlot]) -> List[float]:
        n_a = sum(1 for s in slots if s.device_type != "cpu")
        n_b = len(slots) - n_a
        accel_devs = self._usable_accel_devices()
        # restrict calibration scores to the devices actually in the slots
        by_name = dict(zip((d.name for d in self.accel.devices),
                           self.accel.calibrate()))
        ratios_a = [by_name[d.name] for d in accel_devs]
        tot_r = sum(ratios_a)
        if tot_r > 0:
            ratios_a = [r / tot_r for r in ratios_a]
        if not n_a:
            dist = Distribution(a=0.0, b=1.0)       # degraded: CPU-only
        elif not n_b:
            dist = Distribution(a=1.0, b=0.0)       # degraded: GPU-only
        else:
            dist = Distribution(a=profile.share_a, b=1 - profile.share_a)
        shares: List[float] = []
        if n_a:
            per_dev = [dist.a * r for r in ratios_a]     # static intra-class
            for i, d in enumerate(accel_devs):
                if self.health.is_probing(d.name):       # probation: tiny share
                    per_dev[i] = min(per_dev[i], self.health.probe_share)
            per_queue = []
            for r in per_dev:
                per_queue.extend([r / self.accel.overlap] * self.accel.overlap)
            shares.extend(per_queue)
        if n_b:
            b = dist.b / n_b
            if self.health.is_probing(self.host.device.name):
                b = min(b, self.health.probe_share / n_b)
            shares.extend([b] * n_b)
        # normalise tiny float drift (and probe-share rescaling)
        t = sum(shares)
        if t <= 0:
            # every participating device capped to a zero share (e.g. all
            # probing with probe_share=0): fall back to uniform shares
            # instead of dividing by zero
            return [1.0 / len(shares)] * len(shares)
        return [s / t for s in shares]

    def _make_evaluator(self, sct: SCT, workload: Workload):
        """Evaluator closure for Algorithm 1 over the live executor."""
        def evaluate(cfg: PlatformConfig, dist: Distribution):
            p = Profile(sct_id=sct.unique_id(), workload=workload,
                        share_a=dist.a, config=cfg, best_time=math.inf,
                        origin=Origin.BUILT)
            arrays = self.executor.synthesise_arrays(sct, workload)
            _, stats, _, _, _ = self._dispatch(sct, arrays, p)
            # per-class makespans recorded at dispatch time — one source
            # of truth shared with the balancer and the health tracker
            return stats.total, stats.time_a, stats.time_b
        return evaluate


class _FusedDriver:
    """Admission-queue unit for one flushed fusion batch.

    Occupies one ``max_inflight`` slot (the batch is a single decide +
    dispatch + merge), runs on the shared graph pool, and settles every
    member's handle.  Duck-typed against :class:`GraphDriver` where the
    admission machinery needs it (``handle``, ``start``)."""

    def __init__(self, scheduler: Scheduler, batch: _FusionBatch):
        self.sched = scheduler
        self.batch = batch
        self.handle = batch.members[0].handle   # drain()'s wait probe

    def start(self) -> None:
        self.sched._graph_pool().submit(self._main)

    def _main(self) -> None:
        try:
            self.sched._run_fused(self.batch)
        except BaseException as e:      # defensive: settle, never wedge
            for m in self.batch.members:
                if not m.handle.done():
                    self.sched._settle_member(m, error=e)
        finally:
            self.sched._graph_done(self)


def _array_sig(arrays: Dict[str, Any]) -> Tuple:
    """Shape/dtype identity of submit-time inputs, for whole-graph plan
    keys (values excluded — the cache stores plans, not results)."""
    sig = []
    for k in sorted(arrays):
        v = arrays[k]
        sig.append((k, tuple(int(d) for d in getattr(v, "shape", ())),
                    str(getattr(v, "dtype", type(v).__name__))))
    return tuple(sig)


def infer_workload(sct: SCT, arrays: Dict[str, Any],
                   shapes: Optional[Dict[str, Tuple[int, ...]]] = None
                   ) -> Workload:
    """Workload characterisation from the request arguments (Sec. 3.2.1).

    ``shapes`` supplies global shapes for inputs that are not present in
    ``arrays`` as host arrays — slot-resident vectors on the chained
    path (itemsize defaults to 4 for those, matching the float32
    kernels used throughout).
    """
    for a in sct.free_inputs():
        v = arrays.get(a.name)
        if v is not None and hasattr(v, "shape") and getattr(v, "ndim", 0) >= 1:
            itemsize = getattr(getattr(v, "dtype", None), "itemsize", 4)
            return Workload(tuple(int(d) for d in v.shape), itemsize)
        if shapes and len(shapes.get(a.name, ())) >= 1:
            return Workload(tuple(int(d) for d in shapes[a.name]), 4)
    raise ValueError("cannot characterise workload: no vector argument")
