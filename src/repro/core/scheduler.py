"""Top-level work-distribution decision process (paper Fig. 4 / Sec. 3.2).

The Scheduler receives execution requests from the Library layer and:

  1. on a **new (SCT, workload)** pair — derives a framework configuration
     ("Derive work distribution"): exact KB hit, or scattered-data
     interpolation over collected knowledge; the derived profile is
     persisted (the derivation populates the KB, acting as a cache);
  2. on a **recurrent** pair — checks whether the previous runs were
     unbalanced (lbt detector); if so, either *builds* an SCT profile from
     scratch (Algorithm 1 — only when explicitly enabled and none exists)
     or *adjusts* the current distribution with the adaptive binary search;
  3. dispatches: decomposes the data per the locality-aware plan into the
     per-slot partitions and hands the task group to the executor
     (work queues -> Task Launcher, paper Fig. 2).

The executor is pluggable — :class:`repro.core.executor.ThreadedExecutor`
(real partitioned runs on this host) and
:class:`repro.core.simulator.SimulatedExecutor` share the interface.

Failure semantics
-----------------
Device failure is a first-class scheduling signal, tracked by
:class:`~repro.core.faults.DeviceHealth`: every scheduled run records
per-device success/failure from the executor's fault records; a device
crossing the consecutive-failure threshold is *quarantined* — ``_slots``
and ``_per_slot_shares`` rebuild without it, degrading gracefully to
CPU-only or GPU-only execution — and after a probation interval it
re-enters with a small probe share, one clean run away from full
reinstatement.  Statistics of failed runs are *excluded* from
``LoadBalancer.observe`` and from KB ``best_time`` refinement, so fault
noise cannot corrupt learned profiles; a run whose retries are exhausted
surfaces as :class:`~repro.core.faults.ExecutionError` with the per-slot
fault history attached.
"""
from __future__ import annotations

import collections
import concurrent.futures as cf
import dataclasses
import math
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.autotuner import TunerParams, build_profile
from repro.core.decomposition import (ConcretePartitioning, DecompositionPlan,
                                      ExecutionSlot, build_plan)
from repro.core.distribution import Distribution
from repro.core.faults import DeviceHealth, ExecutionError
from repro.core.graph import GraphDriver, GraphHandle, JobGraph
from repro.core.knowledge_base import (KnowledgeBase, Origin, PlatformConfig,
                                       Profile)
from repro.core.load_balancer import ExecutionStats, LoadBalancer, class_times
from repro.core.platforms import AcceleratorPlatform, HostPlatform
from repro.core.skeletons import SCT
from repro.core.spec import Workload
from repro.core.telemetry import NULL_TELEMETRY, Telemetry


@dataclasses.dataclass
class ScheduledRun:
    """Outcome of one scheduled execution."""

    outputs: Dict[str, Any]
    stats: ExecutionStats
    profile: Profile
    action: str                  # "exact" | "derived" | "built" | "adjusted" | "reused"
    resident_handle: Optional[Any] = None   # slot-resident outputs, if kept

    def detach(self) -> "ScheduledRun":
        """Deep-copy the outputs out of the executor's reusable merge
        buffers, so they survive subsequent runs on the same executor
        (the documented output-aliasing footgun).  Returns ``self``."""
        self.outputs = {k: np.copy(v) if isinstance(v, np.ndarray) else v
                        for k, v in self.outputs.items()}
        return self


class PlanCache:
    """Plan / partitioning cache for recurrent dispatches.

    Two levels, mirroring the two costs on the dispatch path:

      * decomposition plans, keyed by ``(sct_id, input shapes)`` — the
        expensive ``build_plan`` constraint derivation;
      * concrete partitionings, keyed by the full
        ``(sct_id, input shapes, slot signature, shares)`` tuple — the
        quantised largest-remainder allocation.

    The slot signature covers device identity, class and per-kernel wgs,
    and the share vector is part of the key, so any slot-set or
    distribution change self-invalidates by missing.  ``invalidate`` is
    additionally called *explicitly* by the Scheduler whenever the
    device-health version moves (quarantine / probation / reinstatement)
    or a run adjusts the distribution (``adjusted`` / ``built``
    actions), so stale entries are dropped rather than merely bypassed.
    """

    def __init__(self, *, enabled: bool = True, capacity: int = 64):
        self.enabled = enabled
        self.capacity = capacity
        self.hits = 0
        self.misses = 0
        self.invalidations = 0
        self.telemetry: Telemetry = NULL_TELEMETRY
        self._plans: Dict[Tuple, DecompositionPlan] = {}
        self._parts: Dict[Tuple, ConcretePartitioning] = {}

    # -- key components -----------------------------------------------------
    @staticmethod
    def shapes_sig(shapes: Dict[str, Tuple[int, ...]]) -> Tuple:
        return tuple(sorted((k, tuple(int(d) for d in v))
                            for k, v in shapes.items()))

    @staticmethod
    def slot_sig(slots: Sequence[ExecutionSlot]) -> Tuple:
        return tuple((s.device, s.device_type, tuple(sorted(s.wgs.items())))
                     for s in slots)

    @staticmethod
    def share_sig(shares: Sequence[float]) -> Tuple:
        return tuple(round(float(s), 12) for s in shares)

    # -- cache operations ----------------------------------------------------
    def partition(self, sct: SCT, shapes: Dict[str, Tuple[int, ...]],
                  slots: Sequence[ExecutionSlot], shares: Sequence[float]
                  ) -> Tuple[ConcretePartitioning, bool]:
        """Cached equivalent of ``build_plan(...).partition(...)``.

        Returns ``(partitioning, hit)``; with caching disabled this is
        exactly the uncached dispatch path.
        """
        if not self.enabled:
            return build_plan(sct, shapes).partition(slots, shares), False
        key = (sct.unique_id(), self.shapes_sig(shapes),
               self.slot_sig(slots), self.share_sig(shares))
        part = self._parts.get(key)
        if part is not None:
            self.hits += 1
            return part, True
        self.misses += 1
        pkey = key[:2]
        plan = self._plans.get(pkey)
        if plan is None:
            plan = build_plan(sct, shapes)
            self._put(self._plans, pkey, plan)
        part = plan.partition(slots, shares)
        self._put(self._parts, key, part)
        return part, False

    def _put(self, store: Dict, key: Tuple, value) -> None:
        if len(store) >= self.capacity:        # FIFO bound: drop the oldest
            store.pop(next(iter(store)))
        store[key] = value

    def invalidate(self, reason: str = "") -> None:
        """Drop every cached plan/partitioning (slot set or shares moved)."""
        self.invalidations += 1
        self._plans.clear()
        self._parts.clear()
        self.telemetry.metrics.counter("plan_cache_invalidations_total").inc()
        self.telemetry.events.emit("plan_cache.invalidated", reason=reason)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def counters(self) -> Dict[str, float]:
        return {"hits": self.hits, "misses": self.misses,
                "invalidations": self.invalidations,
                "hit_rate": self.hit_rate}


class Scheduler:
    def __init__(self, *, host: HostPlatform, accel: AcceleratorPlatform,
                 executor, kb: Optional[KnowledgeBase] = None,
                 balancer: Optional[LoadBalancer] = None,
                 allow_profile_build: bool = False,
                 tuner_params: TunerParams = TunerParams(),
                 default_share_a: float = 0.8,
                 health: Optional[DeviceHealth] = None,
                 plan_cache: bool = True,
                 telemetry: Optional[Telemetry] = None,
                 max_inflight: int = 4,
                 graph_workers: int = 8):
        self.host = host
        self.accel = accel
        self.executor = executor
        self.kb = kb if kb is not None else KnowledgeBase()
        self.balancer = balancer if balancer is not None else LoadBalancer()
        self.allow_profile_build = allow_profile_build
        self.tuner_params = tuner_params
        self.default_share_a = default_share_a
        self.health = health if health is not None else DeviceHealth()
        self.plan_cache = PlanCache(enabled=plan_cache)
        self._health_seen = self.health.version
        self._last_key: Optional[Tuple[str, str]] = None
        self._current: Optional[Profile] = None
        self._last_slots: List[ExecutionSlot] = []
        self._last_class_times: Tuple[float, float] = (0.0, 0.0)
        self._counts = {"runs": 0, "failed_runs": 0, "retries": 0,
                        "resident_handoffs": 0, "graphs": 0}
        # decision/observation state is shared by concurrent graph nodes;
        # RLock because the autotuner evaluator re-enters _dispatch
        self._lock = threading.RLock()
        # graph admission: FIFO queue, at most max_inflight graphs live
        self.max_inflight = max_inflight
        self.graph_workers = graph_workers
        self._graph_lock = threading.Lock()
        self._admission: "collections.deque[GraphDriver]" = \
            collections.deque()
        self._running: set = set()
        self._graph_seq = 0
        self._graph_pool_obj: Optional[cf.ThreadPoolExecutor] = None
        self._virtual_busy: Dict[str, float] = {}   # virtual-clock queues
        self._closed = False
        self.telemetry = NULL_TELEMETRY
        self.attach_telemetry(telemetry or NULL_TELEMETRY)

    def attach_telemetry(self, telemetry: Telemetry) -> None:
        """Share one telemetry bundle across the whole pipeline.

        Propagated to the plan cache, the executor, the device-health
        tracker and the load balancer, so spans, metrics and events
        from every layer land in a single trace/registry."""
        self.telemetry = telemetry
        self.plan_cache.telemetry = telemetry
        self.health.telemetry = telemetry
        self.balancer.telemetry = telemetry
        if hasattr(self.executor, "telemetry"):
            self.executor.telemetry = telemetry

    # ------------------------------------------------------------------
    def run(self, sct: SCT, arrays: Dict[str, Any],
            workload: Optional[Workload] = None, *,
            _resident=None, _keep_resident: bool = False) -> ScheduledRun:
        """One scheduled execution.  Thread-safe: the decision and
        observation phases serialise on the scheduler lock; the execute
        phase runs unlocked, so independent graph nodes overlap on the
        executor's per-device work queues."""
        shapes = _resident.shapes() if _resident is not None else None
        workload = workload or infer_workload(sct, arrays, shapes=shapes)
        key = (sct.unique_id(), workload.key())

        tel = self.telemetry
        with tel.tracer.span("run", sct=sct.unique_id(),
                             workload=str(workload.key())) as run_span:
            with self._lock:        # decision phase (Fig. 4)
                if key != self._last_key or self._current is None:
                    profile, action = self._derive(sct, workload)
                else:
                    profile, action = self._recurrent(sct, workload)
                self._last_key, self._current = key, profile
                run_span.note(action=action)
                tel.metrics.counter("scheduler_actions_total",
                                    action=action).inc()

                # explicit plan-cache invalidation: distribution adjusted,
                # profile rebuilt, or the device-health state (quarantine /
                # probation / reinstatement) moved since the entries were
                # created
                if action in ("adjusted", "built"):
                    self.plan_cache.invalidate("share adjustment")
                if self.health.version != self._health_seen:
                    self.plan_cache.invalidate("device-health change")
                    self._health_seen = self.health.version

                self.health.tick()
            try:
                outputs, stats, slots, resident_handle = self._dispatch(
                    sct, arrays, profile,
                    resident=_resident, keep_resident=_keep_resident)
            except ExecutionError as e:
                # terminal failure: still feed the health tracker, so repeat
                # offenders get quarantined even when no run ever completes
                # — and never touch the balancer / KB / _last_slots, so a
                # failed run cannot pollute learned state
                with self._lock:
                    for base in {r.device_base for r in e.records}:
                        self.health.record_failure(base)
                    self._counts["runs"] += 1
                    self._counts["failed_runs"] += 1
                tel.metrics.counter("runs_total", status="error").inc()
                tel.events.emit("run.error", level="error",
                                message=str(e), sct=sct.unique_id(),
                                attempts=e.attempts)
                raise
            with self._lock:        # observation phase (Monitor)
                self._last_slots = list(slots)
                self._observe_health(stats)
                self._record_run_metrics(sct, stats, slots)

                # update detector; persist best-known configurations.
                # Failed runs are excluded — their times mix real compute
                # with retry noise and would corrupt the lbt detector and
                # KB profiles.
                if stats.ok:
                    trigger = self.balancer.observe(stats)
                    if not trigger:
                        self.balancer.balanced_again()
                    self._last_class_times = (stats.time_a, stats.time_b)
                    if stats.total < profile.best_time:
                        profile = dataclasses.replace(profile,
                                                      best_time=stats.total)
                        self.kb.store(profile)
                        if self._last_key == key:
                            self._current = profile
            return ScheduledRun(outputs=outputs, stats=stats,
                                profile=profile, action=action,
                                resident_handle=resident_handle)

    def _record_run_metrics(self, sct: SCT, stats: ExecutionStats,
                            slots: Sequence[ExecutionSlot]) -> None:
        """Fold one completed run into counters / metrics / events."""
        tel = self.telemetry
        self._counts["runs"] += 1
        self._counts["retries"] += stats.retries
        if not stats.ok:
            self._counts["failed_runs"] += 1
        if stats.resident:
            self._counts["resident_handoffs"] += 1
        tel.metrics.counter("runs_total",
                            status="ok" if stats.ok else "faulted").inc()
        if stats.retries:
            tel.metrics.counter("retries_total").inc(stats.retries)
            tel.metrics.counter("repartitions_total").inc(stats.retries)
        tel.metrics.counter(
            "plan_cache_hits_total" if stats.plan_cache_hit
            else "plan_cache_misses_total").inc()
        if stats.resident:
            tel.metrics.counter("resident_handoffs_total").inc()
        tel.metrics.counter("merge_bytes_total").inc(stats.merge_bytes)
        tel.metrics.histogram("class_makespan_seconds",
                              cls="a").observe(stats.time_a)
        tel.metrics.histogram("class_makespan_seconds",
                              cls="b").observe(stats.time_b)
        tel.metrics.histogram("overhead_seconds").observe(
            stats.overhead_seconds)
        for slot, t in zip(slots, stats.times):
            tel.metrics.counter("device_busy_seconds_total",
                                device=slot.device.split("/")[0]).inc(t)

    def counters(self) -> Dict[str, float]:
        """One namespaced counter dict across the whole pipeline.

        Folds the plan-cache numbers together with scheduler run/retry
        counts, executor pool reuse and resident handoffs (re-exported
        through :meth:`Session.counters`)."""
        out: Dict[str, float] = {
            f"plan_cache.{k}": v
            for k, v in self.plan_cache.counters().items()}
        with self._lock:
            for k, v in self._counts.items():
                out[f"scheduler.{k}"] = v
        ex = self.executor
        out["executor.pools_created"] = getattr(ex, "pools_created", 0)
        out["executor.pool_reuses"] = getattr(ex, "pool_reuses", 0)
        out["health.quarantined"] = len(self.health.quarantined())
        out["balancer.balance_ops"] = self.balancer.balance_ops
        out["balancer.unbalanced_runs"] = self.balancer.unbalanced_runs
        return out

    def run_chain(self, scts: Sequence[SCT], arrays: Dict[str, Any]
                  ) -> List[ScheduledRun]:
        """Run a compound SCT chain with partitioned residency.

        Each step's slot-local outputs are handed straight to the next
        step (``ResidentPartition``), skipping the merge→re-split round
        trip as long as consecutive steps share the domain decomposition;
        on any mismatch — or on an executor without residency support —
        the handle materialises and the step runs on the ordinary merged
        path.  The final step always merges, so the last
        :class:`ScheduledRun` carries the chain's outputs.  Intermediate
        results that stayed resident are *not* merged back into the
        caller's environment (that is the optimisation).
        """
        supports = bool(getattr(self.executor, "supports_residency", False))
        env = dict(arrays)
        resident = None
        runs: List[ScheduledRun] = []
        for i, sct in enumerate(scts):
            keep = supports and i < len(scts) - 1
            r = self.run(sct, env, _resident=resident,
                         _keep_resident=keep)
            resident = r.resident_handle if keep else None
            if r.outputs:               # merged (final or fallback) results
                env.update(r.outputs)
            runs.append(r)
        return runs

    # -- graph pipeline -------------------------------------------------------
    def submit(self, graph: JobGraph, arrays: Dict[str, Any], *,
               deadline: Optional[float] = None, retries: int = 0,
               retry_backoff: float = 0.05) -> GraphHandle:
        """Admit one JobGraph for execution; returns its handle.

        On the threaded executor the graph enters a FIFO admission queue
        (at most ``max_inflight`` graphs execute at once) and its
        dependency-free nodes start on the node pool immediately after
        admission; nodes on disjoint device slots genuinely overlap.  On
        a virtual-clock executor (``SimulatedExecutor``) the graph runs
        inline, deterministically, on the simulated timeline — the
        handle is already settled when this returns.

        ``deadline`` / ``retries`` / ``retry_backoff`` apply per node,
        with the whole-graph ``deadline`` budget shared across nodes."""
        graph.validate()
        tel = self.telemetry
        with self._graph_lock:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            self._graph_seq += 1
            rid = f"g{self._graph_seq}"
        handle = GraphHandle(graph, rid)
        driver = GraphDriver(self, handle, arrays, deadline=deadline,
                             retries=retries, retry_backoff=retry_backoff)
        with self._lock:
            self._counts["graphs"] += 1
        tel.metrics.counter("graph_nodes_total").inc(len(graph))
        tel.events.emit("graph.submitted", request=rid, nodes=len(graph))
        if getattr(self.executor, "virtual_clock", False):
            driver.run_virtual()
            return handle
        with self._graph_lock:
            self._admission.append(driver)
            started = self._pump_locked()
        for d in started:
            d.start()
        return handle

    def _pump_locked(self) -> List[GraphDriver]:
        """Admit queued graphs up to ``max_inflight``; caller holds
        ``_graph_lock`` and must ``start()`` the returned drivers."""
        started: List[GraphDriver] = []
        while self._admission and len(self._running) < self.max_inflight:
            d = self._admission.popleft()
            self._running.add(d)
            started.append(d)
        return started

    def _graph_done(self, driver: GraphDriver) -> None:
        """Completion callback from a GraphDriver: admit the next graph."""
        with self._graph_lock:
            self._running.discard(driver)
            started = self._pump_locked()
        for d in started:
            d.start()

    def _graph_pool(self) -> cf.ThreadPoolExecutor:
        """Lazily created node pool shared by every admitted graph."""
        with self._graph_lock:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            if self._graph_pool_obj is None:
                self._graph_pool_obj = cf.ThreadPoolExecutor(
                    max_workers=self.graph_workers,
                    thread_name_prefix="graph-node")
            return self._graph_pool_obj

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted graph settles (or ``timeout``
        seconds elapse); returns True when fully drained."""
        t0 = time.monotonic()
        while True:
            with self._graph_lock:
                live = list(self._running) + list(self._admission)
            if not live:
                return True
            if timeout is not None and time.monotonic() - t0 > timeout:
                return False
            live[0].handle.wait(0.05)

    def close(self) -> None:
        """Drain in-flight graphs, stop admission, release the node pool
        and the executor's resources.  Idempotent."""
        self.drain()
        with self._graph_lock:
            self._closed = True
            pool, self._graph_pool_obj = self._graph_pool_obj, None
        if pool is not None:
            pool.shutdown(wait=True)
        close = getattr(self.executor, "close", None)
        if close is not None:
            close()

    def _observe_health(self, stats) -> None:
        """Feed per-device success/failure of one run into the tracker."""
        failed = {r.device_base for r in stats.failures}
        participated = {s.device.split("/")[0] for s in self._last_slots}
        for base in participated - failed:
            self.health.record_success(base)
        for base in failed:
            self.health.record_failure(base)

    # ------------------------------------------------------------------
    def _derive(self, sct: SCT, workload: Workload) -> Tuple[Profile, str]:
        exact = self.kb.exact(sct.unique_id(), workload)
        if exact is not None:
            return exact, "exact"
        derived = self.kb.derive(sct.unique_id(), workload)
        if derived is not None:
            self.kb.store(derived)
            return derived, "derived"
        # empty KB: assume-good default, to be refined online (paper: the KB
        # is assumed sufficient; adjustments correct over-optimism)
        p = Profile(sct_id=sct.unique_id(), workload=workload,
                    share_a=self.default_share_a, config=PlatformConfig(),
                    best_time=math.inf, origin=Origin.DERIVED)
        self.kb.store(p)
        return p, "derived"

    def _recurrent(self, sct: SCT, workload: Workload) -> Tuple[Profile, str]:
        assert self._current is not None
        unbalanced = self.balancer.lbt >= self.balancer.trigger
        if not unbalanced:
            return self._current, "reused"
        have_built = (self._current.origin is Origin.BUILT)
        if self.allow_profile_build and not have_built:
            result = build_profile(
                sct.unique_id(), workload, host=self.host, accel=self.accel,
                evaluate=self._make_evaluator(sct, workload),
                params=self.tuner_params, kb=self.kb, sct=sct)
            self.balancer.reset_search()
            self.balancer.lbt = 0.0
            return result.profile, "built"
        # Adjust workload distribution (adaptive binary search) from the
        # last observed per-class makespans (scheduler-owned state: the
        # executor's last_* fields are not stable under concurrent nodes)
        last = self._last_class_times
        cur = Distribution(a=self._current.share_a, b=1 - self._current.share_a)
        new = self.balancer.adjust(cur, last[0], last[1])
        adjusted = dataclasses.replace(self._current, share_a=new.a,
                                       best_time=math.inf)
        return adjusted, "adjusted"

    # ------------------------------------------------------------------
    def _dispatch(self, sct: SCT, arrays: Dict[str, Any], profile: Profile,
                  *, resident=None, keep_resident: bool = False
                  ) -> Tuple[Dict[str, Any], ExecutionStats,
                             List[ExecutionSlot], Any]:
        """Plan + execute one run; returns (outputs, stats, slots,
        resident handle).  The plan phase (slot generation, plan cache)
        serialises on the scheduler lock; execution does not."""
        t0 = time.perf_counter()
        with self._lock:
            with self.telemetry.tracer.span("plan") as plan_span:
                shapes = {k: tuple(getattr(v, "shape", ()))
                          for k, v in arrays.items()}
                if resident is not None:
                    # slot-resident vectors are inputs too: plan over their
                    # global (merged) shapes without materialising them
                    shapes = {**resident.shapes(), **shapes}
                slots = self._slots(profile)
                shares = self._per_slot_shares(profile, slots)
                part, cache_hit = self.plan_cache.partition(sct, shapes,
                                                            slots, shares)
                plan_span.note(cache_hit=cache_hit, slots=len(slots))
        plan_seconds = time.perf_counter() - t0

        kwargs: Dict[str, Any] = {}
        if getattr(self.executor, "supports_residency", False):
            kwargs = {"resident": resident, "keep_resident": keep_resident}
        execute_result = getattr(self.executor, "execute_result", None)
        if execute_result is not None:
            # per-call result object: safe under concurrent graph nodes
            res = execute_result(sct, part, arrays, profile, **kwargs)
            outputs, times = res.outputs, res.times
            failures, retries = res.failures, res.retries
            timing = dict(res.timing or {})
            merge_bytes = res.merge_bytes
            resident_out = res.resident
        else:
            # legacy duck-typed executor: observe through last_* fields
            outputs, times = self.executor.execute(sct, part, arrays,
                                                   profile, **kwargs)
            failures = list(getattr(self.executor, "last_failures", []))
            retries = int(getattr(self.executor, "last_retries", 0))
            timing = dict(getattr(self.executor, "last_timing", {}) or {})
            merge_bytes = int(getattr(self.executor, "last_merge_bytes", 0))
            resident_out = getattr(self.executor, "last_resident", None)
        n_a = sum(1 for s in slots if s.device_type != "cpu")
        ta, tb = class_times(times, n_a)
        stats = ExecutionStats(
            times=list(times), share_a=profile.share_a, time_a=ta, time_b=tb,
            failures=failures,
            retries=retries,
            plan_seconds=plan_seconds,
            pool_seconds=float(timing.get("pool", 0.0)),
            dispatch_seconds=float(timing.get("dispatch", 0.0)),
            compute_seconds=float(timing.get("compute", 0.0)),
            merge_seconds=float(timing.get("merge", 0.0)),
            merge_bytes=merge_bytes,
            plan_cache_hit=cache_hit,
            resident=resident_out is not None)
        return outputs, stats, list(slots), resident_out

    def _usable_accel_devices(self):
        return [d for d in self.accel.devices if self.health.usable(d.name)]

    def _slots(self, profile: Profile) -> List[ExecutionSlot]:
        """Accelerator slots first (class a), then host fission slots.

        Quarantined devices are excluded — the run degrades gracefully to
        CPU-only or GPU-only; a device due for probation re-enters here
        (with a probe-sized share, see :meth:`_per_slot_shares`).
        """
        self.host.configure(profile.config.fission_level)
        self.accel.configure(profile.config.overlap)
        slots: List[ExecutionSlot] = []
        for d in self._usable_accel_devices():
            for o in range(self.accel.overlap):
                slots.append(ExecutionSlot(device=f"{d.name}/q{o}",
                                           device_type=d.kind,
                                           wgs=dict(profile.config.wgs)))
        if self.health.usable(self.host.device.name):
            for i in range(self.host.parallelism):
                slots.append(ExecutionSlot(
                    device=f"{self.host.device.name}/f{i}",
                    device_type="cpu", wgs=dict(profile.config.wgs)))
        if not slots:
            raise ExecutionError(
                "all devices quarantined: no execution slots available "
                f"(quarantined: {sorted(self.health.quarantined())})")
        return slots

    def _per_slot_shares(self, profile: Profile,
                         slots: Sequence[ExecutionSlot]) -> List[float]:
        n_a = sum(1 for s in slots if s.device_type != "cpu")
        n_b = len(slots) - n_a
        accel_devs = self._usable_accel_devices()
        # restrict calibration scores to the devices actually in the slots
        by_name = dict(zip((d.name for d in self.accel.devices),
                           self.accel.calibrate()))
        ratios_a = [by_name[d.name] for d in accel_devs]
        tot_r = sum(ratios_a)
        if tot_r > 0:
            ratios_a = [r / tot_r for r in ratios_a]
        if not n_a:
            dist = Distribution(a=0.0, b=1.0)       # degraded: CPU-only
        elif not n_b:
            dist = Distribution(a=1.0, b=0.0)       # degraded: GPU-only
        else:
            dist = Distribution(a=profile.share_a, b=1 - profile.share_a)
        shares: List[float] = []
        if n_a:
            per_dev = [dist.a * r for r in ratios_a]     # static intra-class
            for i, d in enumerate(accel_devs):
                if self.health.is_probing(d.name):       # probation: tiny share
                    per_dev[i] = min(per_dev[i], self.health.probe_share)
            per_queue = []
            for r in per_dev:
                per_queue.extend([r / self.accel.overlap] * self.accel.overlap)
            shares.extend(per_queue)
        if n_b:
            b = dist.b / n_b
            if self.health.is_probing(self.host.device.name):
                b = min(b, self.health.probe_share / n_b)
            shares.extend([b] * n_b)
        # normalise tiny float drift (and probe-share rescaling)
        t = sum(shares)
        if t <= 0:
            # every participating device capped to a zero share (e.g. all
            # probing with probe_share=0): fall back to uniform shares
            # instead of dividing by zero
            return [1.0 / len(shares)] * len(shares)
        return [s / t for s in shares]

    def _make_evaluator(self, sct: SCT, workload: Workload):
        """Evaluator closure for Algorithm 1 over the live executor."""
        def evaluate(cfg: PlatformConfig, dist: Distribution):
            p = Profile(sct_id=sct.unique_id(), workload=workload,
                        share_a=dist.a, config=cfg, best_time=math.inf,
                        origin=Origin.BUILT)
            arrays = self.executor.synthesise_arrays(sct, workload)
            _, stats, _, _ = self._dispatch(sct, arrays, p)
            # per-class makespans recorded at dispatch time — one source
            # of truth shared with the balancer and the health tracker
            return stats.total, stats.time_a, stats.time_b
        return evaluate


def infer_workload(sct: SCT, arrays: Dict[str, Any],
                   shapes: Optional[Dict[str, Tuple[int, ...]]] = None
                   ) -> Workload:
    """Workload characterisation from the request arguments (Sec. 3.2.1).

    ``shapes`` supplies global shapes for inputs that are not present in
    ``arrays`` as host arrays — slot-resident vectors on the chained
    path (itemsize defaults to 4 for those, matching the float32
    kernels used throughout).
    """
    for a in sct.free_inputs():
        v = arrays.get(a.name)
        if v is not None and hasattr(v, "shape") and getattr(v, "ndim", 0) >= 1:
            itemsize = getattr(getattr(v, "dtype", None), "itemsize", 4)
            return Workload(tuple(int(d) for d in v.shape), itemsize)
        if shapes and len(shapes.get(a.name, ())) >= 1:
            return Workload(tuple(int(d) for d in shapes[a.name]), 4)
    raise ValueError("cannot characterise workload: no vector argument")
