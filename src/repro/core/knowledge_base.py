"""Knowledge Base + configuration derivation (paper Sec. 3.2.1 / 3.2.3).

The KB stores :class:`Profile` records — everything needed to reproduce a
framework configuration for one (SCT, workload) pair:

  a) SCT unique identifier,
  b) workload characterisation (dims, element size),
  c) workload share per device (class),
  d) per-device execution-platform configuration (fission level, overlap
     factor, per-kernel work-group/block sizes),
  e) minimum execution time measured for this configuration,
  f) the generation process: BUILT (empirical, Algorithm 1) or DERIVED.

Configuration derivation for an unseen (SCT, workload) applies
multidimensional scattered-data interpolation over the collected profiles:

  * workload dimensionality 1–3  ->  Gaussian **RBF network** (the paper
    uses Alglib's fast RBF; we implement the classical regularised RBF
    solve in numpy — identical model class),
  * dimensionality  > 3          ->  **nearest neighbour** (Euclidean).

Scope-widening rules (paper): first interpolate over profiles of the *same
SCT*; failing that, profiles of the *same workload* under any SCT; failing
that, any profile of the same *dimensionality*.
"""
from __future__ import annotations

import dataclasses
import enum
import json
import math
import os
import tempfile
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.spec import Workload


class Origin(enum.Enum):
    BUILT = "built"       # empirical profile construction (Algorithm 1)
    DERIVED = "derived"   # interpolated from the KB


@dataclasses.dataclass
class PlatformConfig:
    """Execution-platform configuration (paper Sec. 3.2.1 item d).

    TPU adaptation: ``fission_level`` = mesh-fission level of the host/slow
    class; ``overlap`` = in-flight microbatch depth of the accelerator
    class; ``wgs`` = per-kernel work-group (block) sizes.
    """

    fission_level: str = "NO_FISSION"
    overlap: int = 1
    wgs: Dict[str, int] = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict:
        return {"fission_level": self.fission_level, "overlap": self.overlap,
                "wgs": dict(self.wgs)}

    @staticmethod
    def from_json(d: Dict) -> "PlatformConfig":
        return PlatformConfig(fission_level=d["fission_level"],
                              overlap=int(d["overlap"]),
                              wgs={k: int(v) for k, v in d["wgs"].items()})


@dataclasses.dataclass
class Profile:
    sct_id: str
    workload: Workload
    share_a: float                      # fast-class (GPU) share of the work
    config: PlatformConfig
    best_time: float = math.inf
    origin: Origin = Origin.BUILT

    @property
    def share_b(self) -> float:
        return 1.0 - self.share_a

    def key(self) -> Tuple[str, str]:
        return (self.sct_id, self.workload.key())

    def to_json(self) -> Dict:
        return {"sct_id": self.sct_id,
                "dims": list(self.workload.dims),
                "itemsize": self.workload.itemsize,
                "share_a": self.share_a,
                "config": self.config.to_json(),
                "best_time": self.best_time,
                "origin": self.origin.value}

    @staticmethod
    def from_json(d: Dict) -> "Profile":
        return Profile(sct_id=d["sct_id"],
                       workload=Workload(tuple(d["dims"]), d["itemsize"]),
                       share_a=float(d["share_a"]),
                       config=PlatformConfig.from_json(d["config"]),
                       best_time=float(d["best_time"]),
                       origin=Origin(d["origin"]))


# ---------------------------------------------------------------------------
# Scattered-data interpolation
# ---------------------------------------------------------------------------

class RBFNetwork:
    """Regularised Gaussian radial-basis-function network.

    phi(r) = exp(-(r/sigma)^2); weights from the regularised linear solve
    (Phi + lam*I) w = y.  Features are standardised (zero mean / unit std)
    before fitting — workload dims span orders of magnitude.
    """

    def __init__(self, sigma: Optional[float] = None, lam: float = 1e-8):
        self.sigma = sigma
        self.lam = lam
        self._x: Optional[np.ndarray] = None
        self._w: Optional[np.ndarray] = None
        self._mu: Optional[np.ndarray] = None
        self._sd: Optional[np.ndarray] = None

    def fit(self, x: np.ndarray, y: np.ndarray) -> "RBFNetwork":
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if x.ndim != 2 or len(x) != len(y):
            raise ValueError("bad RBF training data")
        self._mu = x.mean(axis=0)
        self._sd = np.where(x.std(axis=0) > 0, x.std(axis=0), 1.0)
        xs = (x - self._mu) / self._sd
        if self.sigma is None:
            # median pairwise distance heuristic
            if len(xs) > 1:
                d = np.sqrt(((xs[:, None, :] - xs[None, :, :]) ** 2).sum(-1))
                med = float(np.median(d[d > 0])) if (d > 0).any() else 1.0
                self.sigma = max(med, 1e-6)
            else:
                self.sigma = 1.0
        phi = self._phi(xs, xs)
        n = len(xs)
        self._w = np.linalg.solve(phi + self.lam * np.eye(n), y)
        self._x = xs
        return self

    def _phi(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
        return np.exp(-d2 / (self.sigma ** 2))

    def predict(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, dtype=np.float64)
        one = x.ndim == 1
        if one:
            x = x[None, :]
        xs = (x - self._mu) / self._sd
        out = self._phi(xs, self._x) @ self._w
        return out[0] if one else out


def nearest_neighbour(x: np.ndarray, pts: np.ndarray) -> int:
    """Index of the Euclidean nearest neighbour (log-scaled features)."""
    lx = np.log1p(np.asarray(x, dtype=np.float64))
    lp = np.log1p(np.asarray(pts, dtype=np.float64))
    d = ((lp - lx[None, :]) ** 2).sum(-1)
    return int(np.argmin(d))


# ---------------------------------------------------------------------------
# The Knowledge Base
# ---------------------------------------------------------------------------

class KnowledgeBase:
    """Profile store + inference engine (paper Fig. 2 / Sec. 3.2.3)."""

    RBF_MAX_DIM = 3   # paper: RBF for dims 1..3, NN beyond

    def __init__(self, path: Optional[str] = None):
        self._profiles: Dict[Tuple[str, str], Profile] = {}
        # concurrent graph nodes store/derive from multiple scheduler
        # threads; RLock because store() may nest inside derive()/save()
        self._lock = threading.RLock()
        self.path = path
        if path and os.path.exists(path):
            self.load(path)

    # -- storage ------------------------------------------------------------
    def store(self, profile: Profile) -> None:
        """Persist a profile, keeping only the best time per (SCT, workload).

        ``best_time`` must be positive (or ``inf`` for not-yet-measured
        profiles): NaN / non-positive times — e.g. from a run that
        suffered slot faults and was mis-reported — are rejected so fault
        noise can never displace a genuinely measured best configuration
        (the Scheduler additionally excludes failed runs upstream).
        """
        if math.isnan(profile.best_time) or profile.best_time <= 0:
            raise ValueError(
                f"refusing to store profile with best_time="
                f"{profile.best_time!r} for {profile.key()}")
        with self._lock:
            k = profile.key()
            old = self._profiles.get(k)
            if old is None or profile.best_time <= old.best_time:
                self._profiles[k] = profile
                if self.path:
                    self.save(self.path)

    def exact(self, sct_id: str, workload: Workload) -> Optional[Profile]:
        with self._lock:
            return self._profiles.get((sct_id, workload.key()))

    def __len__(self) -> int:
        with self._lock:
            return len(self._profiles)

    def profiles(self) -> List[Profile]:
        with self._lock:
            return list(self._profiles.values())

    # -- persistence (atomic) -------------------------------------------------
    def save(self, path: str) -> None:
        with self._lock:
            payload = json.dumps(
                [p.to_json() for p in self._profiles.values()], indent=1)
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".kb.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(payload)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    def load(self, path: str) -> None:
        with open(path) as f:
            records = json.load(f)
        with self._lock:
            for d in records:
                p = Profile.from_json(d)
                self._profiles[p.key()] = p

    # -- derivation (paper Sec. 3.2.3) ---------------------------------------
    def derive(self, sct_id: str, workload: Workload) -> Optional[Profile]:
        """Derive a configuration for an unseen (SCT, workload).

        Scope widening: same-SCT profiles -> same-workload profiles (any
        SCT) -> same-dimensionality profiles.  Returns ``None`` only when
        the KB is empty of usable data.
        """
        hit = self.exact(sct_id, workload)
        if hit is not None:
            return hit
        with self._lock:
            pool = list(self._profiles.values())
        scopes = (
            [p for p in pool if p.sct_id == sct_id
             and p.workload.ndim == workload.ndim],
            [p for p in pool
             if p.workload.key() == workload.key()],
            [p for p in pool
             if p.workload.ndim == workload.ndim],
        )
        for cand in scopes:
            if cand:
                return self._interpolate(sct_id, workload, cand)
        return None

    def _interpolate(self, sct_id: str, workload: Workload,
                     cand: Sequence[Profile]) -> Profile:
        feats = np.array([p.workload.as_features() for p in cand])
        target = np.array(workload.as_features())
        nn = cand[nearest_neighbour(target, feats)]
        if workload.ndim <= self.RBF_MAX_DIM and len(cand) >= 2:
            # interpolate the continuous quantities with the RBF network;
            # discrete platform choices come from the nearest neighbour.
            try:
                lf = np.log1p(feats)
                lt = np.log1p(target)
                share = float(np.clip(
                    RBFNetwork().fit(lf, np.array([p.share_a for p in cand]))
                    .predict(lt), 0.0, 1.0))
                overlap = int(round(float(np.clip(
                    RBFNetwork().fit(
                        lf, np.array([float(p.config.overlap) for p in cand]))
                    .predict(lt), 1, 64))))
            except np.linalg.LinAlgError:
                share, overlap = nn.share_a, nn.config.overlap
        else:
            share, overlap = nn.share_a, nn.config.overlap
        cfg = PlatformConfig(fission_level=nn.config.fission_level,
                             overlap=overlap, wgs=dict(nn.config.wgs))
        return Profile(sct_id=sct_id, workload=workload, share_a=share,
                       config=cfg, best_time=math.inf, origin=Origin.DERIVED)
