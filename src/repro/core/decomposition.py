"""Locality-aware domain decomposition (paper Sec. 3.1).

The dataset of an SCT is partitioned **once**, with a global vision of the
whole tree, so that consecutive kernels communicate by simply *persisting*
data in device memory — never by moving it between devices.  Two kernels
that share a vector must observe identical partitionings (same number of
partitions, same sizes), regardless of their individual work-group size
restrictions.

Paper constraint system, for vector V shared by kernels K with partitions
``V^j`` (one per parallel execution j):

    V = U_j V^j
    epu(V) mod nu(V, K) == 0
    #V^j  mod (epu(V) / nu(V, K)) == 0
    #V^j  mod wgs_j(K) == 0

Implementation: all partitionable vectors of an SCT are decomposed over a
common *domain* expressed in elementary partitioning units.  Vector V with
extent ``e`` along its partition dim contributes ``e / epu(V)`` domain
units, and every partitionable vector must agree on that unit count.
Execution j receives ``u_j`` units, where ``u_j`` must be a multiple of the
execution's *unit quantum* ``q_j = lcm_K( lcm(wgs_j(K), epu) / epu )``.

TPU adaptation — the same plan drives two backends:
  * explicit per-partition execution (``shard_map`` / simulator / CPU),
    where partitions may be **uneven** (heterogeneous devices);
  * GSPMD (``pjit``), where the plan degenerates to even sharding and is
    emitted as ``NamedSharding`` per SCT edge (sharding-stable edges = the
    paper's "persist data on device" rule: XLA inserts no resharding
    collectives between consecutive kernels).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.skeletons import SCT
from repro.core.spec import ArgSpec, KernelSpec, Transfer


class DecompositionError(ValueError):
    pass


@dataclasses.dataclass(frozen=True)
class VectorPlan:
    name: str
    partition_dim: int
    epu: int
    copy: bool                      # COPY transfer mode -> replicate
    extent: int                     # size along partition_dim
    units: int                      # extent / epu (0 for COPY vectors)


@dataclasses.dataclass
class ExecutionSlot:
    """One parallel execution (paper Fig. 3): a (device, queue) pair.

    ``wgs``: work-group size chosen for each kernel on this slot's device
    (kernel name -> wgs).  ``device_type``: 'cpu' / 'gpu' / 'tpu' class
    used by the workload-distribution generator.
    """

    device: str
    device_type: str
    wgs: Dict[str, int] = dataclasses.field(default_factory=dict)

    def quantum(self, vectors: Sequence[VectorPlan],
                specs: Sequence[KernelSpec]) -> int:
        """Unit quantum of this execution: u_j must be a multiple of it."""
        q = 1
        for spec in specs:
            wgs = self.wgs.get(spec.name, 1)
            for a in spec.vectors:
                if a.transfer is Transfer.COPY or not a.partitionable:
                    continue
                # paper: epu(V) mod nu(V,K) == 0
                if a.epu % spec.nu(a.name) != 0:
                    raise DecompositionError(
                        f"kernel {spec.name}, vector {a.name}: "
                        f"epu={a.epu} not a multiple of nu={spec.nu(a.name)}")
                # #V^j mod wgs == 0  ->  u_j mod lcm(wgs, epu)/epu == 0
                q = math.lcm(q, math.lcm(wgs, a.epu) // a.epu)
        return q


@dataclasses.dataclass
class DecompositionPlan:
    """Partitioning plan for one (SCT, workload) pair."""

    sct_id: str
    domain_units: int
    vectors: Dict[str, VectorPlan]
    specs: List[KernelSpec]

    # ---- explicit (possibly uneven) partitioning -------------------------
    def partition(self, slots: Sequence[ExecutionSlot],
                  shares: Sequence[float]) -> "ConcretePartitioning":
        """Quantised largest-remainder allocation of domain units to slots.

        ``shares`` come from the workload-distribution generator; they are
        quantised to each slot's unit quantum.  If an exact allocation is
        impossible the most-loaded slot's quantum is relaxed to 1 (paper:
        when constraints cannot hold, the best-occupancy work-group size is
        used instead — the solution may be inherently unbalanced).
        """
        if len(slots) != len(shares):
            raise DecompositionError("one share per execution slot required")
        if abs(sum(shares) - 1.0) > 1e-6:
            raise DecompositionError(f"shares must sum to 1, got {sum(shares)}")
        U = self.domain_units
        quanta = [s.quantum(list(self.vectors.values()), self.specs)
                  for s in slots]
        alloc = [int(f * U) // q * q for f, q in zip(shares, quanta)]
        rem = U - sum(alloc)
        # greedy fill by largest fractional remainder, in quantum steps
        order = sorted(range(len(slots)),
                       key=lambda i: (shares[i] * U - alloc[i]), reverse=True)
        progress = True
        while rem > 0 and progress:
            progress = False
            for i in order:
                if quanta[i] <= rem:
                    alloc[i] += quanta[i]
                    rem -= quanta[i]
                    progress = True
        relaxed = False
        if rem > 0:  # relax the largest slot's quantum (paper fallback)
            j = max(range(len(slots)), key=lambda i: alloc[i])
            alloc[j] += rem
            rem = 0
            relaxed = True
        return ConcretePartitioning(plan=self, slots=list(slots),
                                    units=alloc, relaxed=relaxed)

    # ---- GSPMD path -------------------------------------------------------
    def shardings(self, mesh: Mesh, *, data_axis: str = "data",
                  extra: Optional[Dict[str, P]] = None
                  ) -> Dict[str, NamedSharding]:
        """Even sharding per SCT edge: one NamedSharding per vector.

        COPY vectors are replicated; partitionable vectors are sharded
        along their partition dim over ``data_axis``.  Raises if the even
        per-device partition would violate the quantum constraints.
        """
        n = mesh.shape[data_axis]
        out: Dict[str, NamedSharding] = {}
        if self.domain_units % n != 0:
            raise DecompositionError(
                f"domain has {self.domain_units} units, not divisible by "
                f"mesh axis '{data_axis}'={n}")
        for name, v in self.vectors.items():
            if v.copy:
                spec = P()
            else:
                axes: List[Optional[str]] = [None] * (v.partition_dim + 1)
                axes[v.partition_dim] = data_axis
                spec = P(*axes)
            if extra and name in extra:
                spec = extra[name]
            out[name] = NamedSharding(mesh, spec)
        return out


@dataclasses.dataclass
class ConcretePartitioning:
    plan: DecompositionPlan
    slots: List[ExecutionSlot]
    units: List[int]            # domain units per execution slot
    relaxed: bool = False

    def sizes(self, vector: str) -> List[int]:
        v = self.plan.vectors[vector]
        if v.copy:
            return [v.extent] * len(self.slots)
        return [u * v.epu for u in self.units]

    def offsets(self, vector: str) -> List[int]:
        v = self.plan.vectors[vector]
        if v.copy:
            return [0] * len(self.slots)
        offs, acc = [], 0
        for u in self.units:
            offs.append(acc)
            acc += u * v.epu
        return offs

    def slices(self, vector: str, array):
        """Materialise the per-slot slices of a host array."""
        v = self.plan.vectors[vector]
        if v.copy:
            return [array] * len(self.slots)
        out = []
        for off, size in zip(self.offsets(vector), self.sizes(vector)):
            idx = [slice(None)] * array.ndim
            idx[v.partition_dim] = slice(off, off + size)
            out.append(array[tuple(idx)])
        return out

    def shares(self) -> List[float]:
        U = max(1, self.plan.domain_units)
        return [u / U for u in self.units]

    def layout(self) -> Tuple[Tuple[int, int], ...]:
        """Planned ``(start, units)`` domain range per slot, in order.

        This is the canonical segment layout of a fault-free run; the
        executor compares it against a :class:`ResidentPartition`'s
        realised layout to decide whether slot-local outputs can be
        handed straight to the next SCT (zero-copy chaining) or must be
        merged first.
        """
        out: List[Tuple[int, int]] = []
        acc = 0
        for u in self.units:
            out.append((acc, u))
            acc += u
        return tuple(out)

    def same_layout(self, other: "ConcretePartitioning") -> bool:
        """True when both partitionings tile the same domain identically."""
        return (self.plan.domain_units == other.plan.domain_units
                and list(self.units) == list(other.units))


def build_plan(sct: SCT, shapes: Dict[str, Tuple[int, ...]]) -> DecompositionPlan:
    """Derive the locality-aware decomposition plan for an SCT.

    ``shapes`` maps every free input (and, where they differ from inputs,
    produced vectors) to its global shape.  Output shapes not given are
    inferred to inherit their producing kernel's partition behaviour.
    """
    specs = sct.kernel_specs()
    vectors: Dict[str, VectorPlan] = {}
    units: Optional[int] = None
    unit_witness = ""
    for spec in specs:
        for a in spec.vectors:
            shape = shapes.get(a.name)
            if shape is None:
                continue
            copy = a.transfer is Transfer.COPY
            extent = int(shape[a.partition_dim]) if not copy else int(
                shape[a.partition_dim])
            if not copy:
                if extent % a.epu != 0:
                    raise DecompositionError(
                        f"vector {a.name}: extent {extent} not a multiple of "
                        f"epu {a.epu}")
                u = extent // a.epu
                if units is None:
                    units, unit_witness = u, a.name
                elif u != units:
                    raise DecompositionError(
                        "locality violation: vectors "
                        f"'{unit_witness}' ({units} units) and '{a.name}' "
                        f"({u} units) disagree on the partition domain")
            prev = vectors.get(a.name)
            if prev is not None:
                if (prev.partition_dim != a.partition_dim
                        or prev.copy != copy
                        or (not copy and prev.epu != a.epu)):
                    raise DecompositionError(
                        f"vector {a.name}: conflicting partition specs "
                        "between kernels sharing the edge")
                continue
            vectors[a.name] = VectorPlan(
                name=a.name, partition_dim=a.partition_dim, epu=a.epu,
                copy=copy, extent=extent,
                units=0 if copy else extent // a.epu)
    if units is None:
        raise DecompositionError("SCT has no partitionable vector with a "
                                 "known shape")
    return DecompositionPlan(sct_id=sct.unique_id(), domain_units=units,
                             vectors=vectors, specs=specs)


def validate(plan: DecompositionPlan, part: ConcretePartitioning) -> None:
    """Check the paper's constraint system on a concrete partitioning."""
    for name, v in plan.vectors.items():
        if v.copy:
            continue
        sizes = part.sizes(name)
        if sum(sizes) != v.extent:
            raise DecompositionError(f"{name}: partitions do not cover domain")
        for j, (slot, size) in enumerate(zip(part.slots, sizes)):
            for spec in plan.specs:
                try:
                    a = spec.arg(name)
                except KeyError:
                    continue
                nu = spec.nu(name)
                if a.epu % nu != 0:
                    raise DecompositionError(
                        f"{name}/K={spec.name}: epu%nu != 0")
                if size % (a.epu // nu) != 0:
                    raise DecompositionError(
                        f"{name}/K={spec.name}/exec{j}: size {size} not a "
                        f"multiple of epu/nu={a.epu // nu}")
                wgs = slot.wgs.get(spec.name)
                if wgs and not part.relaxed and size % wgs != 0:
                    raise DecompositionError(
                        f"{name}/K={spec.name}/exec{j}: size {size} not a "
                        f"multiple of wgs={wgs}")
