"""Fault-tolerance layer: error taxonomy, injection, health tracking.

The paper's scheduler adapts to *slow* devices (the lbt detector and the
adaptive binary search of Sec. 3.3) but assumes every execution slot
always completes.  Production heterogeneous runtimes (EngineCL's device
dropout handling; Kothapalli et al.'s cross-device-class fallback) must
treat device *failure* and *stalls* as first-class scheduling signals.
This module provides the shared vocabulary:

Error taxonomy
  * :class:`SlotFailure`     — one execution slot raised; recoverable by
    re-partitioning its slice across the surviving slots.
  * :class:`SlotTimeout`     — a slot exceeded its watchdog deadline
    (derived from ``profile.best_time``); treated like a crash, but the
    device is additionally suspected of being hung.
  * :class:`PartitionLost`   — a slice could not be recovered because no
    surviving slot can take it (all peers dead or quarantined).
  * :class:`ExecutionError`  — terminal: retries exhausted (or no
    capacity left).  Carries the per-slot :class:`FaultRecord` history.

Determinism
  :class:`FaultInjector` produces crashes/stalls from a seeded counter —
  per-slot crash probability, stall injection, and exact Nth-call
  triggers — so pod-scale failure policies are testable bit-for-bit on
  both the threaded executor and the simulator.

Health
  :class:`DeviceHealth` tracks consecutive per-device failures; devices
  that cross the quarantine threshold are excluded from slot generation
  until a probationary probe run succeeds (graceful degradation down to
  CPU-only or GPU-only execution).
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.telemetry import NULL_TELEMETRY


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FaultRecord:
    """One observed slot-level fault (crash / timeout / lost partition)."""

    slot: int                   # index of the slot within the partitioning
    device: str                 # e.g. "gpu0/q1", "cpu/f3"
    device_type: str            # "cpu" | "gpu" | "tpu"
    kind: str                   # "crash" | "timeout" | "lost"
    attempt: int                # 0-based retry round the fault occurred in
    message: str = ""
    seconds: float = 0.0        # elapsed before the fault was observed

    @property
    def device_base(self) -> str:
        """Physical device name without the queue/fission suffix."""
        return self.device.split("/")[0]

    def __str__(self) -> str:
        return (f"[attempt {self.attempt}] slot {self.slot} "
                f"({self.device}, {self.device_type}): {self.kind}"
                + (f" — {self.message}" if self.message else ""))


class SlotFailure(RuntimeError):
    """A single execution slot failed; the run may still be recovered."""

    def __init__(self, record: FaultRecord):
        super().__init__(str(record))
        self.record = record


class SlotTimeout(SlotFailure):
    """A slot exceeded its watchdog deadline (hung device / stalled queue)."""


class PartitionLost(SlotFailure):
    """A lost slice has no surviving slot able to adopt it."""


class ExecutionError(RuntimeError):
    """Terminal failure of a scheduled run: retries exhausted.

    ``records`` is the full per-slot fault history across attempts, so
    callers (and ``Future.get``) can report *which* device failed rather
    than a bare pool exception.
    """

    def __init__(self, message: str,
                 records: Sequence[FaultRecord] = (),
                 attempts: int = 0):
        self.records = list(records)
        self.attempts = attempts
        detail = "; ".join(str(r) for r in self.records)
        super().__init__(message + (f" [{detail}]" if detail else ""))


class InjectedFault(RuntimeError):
    """Raised inside a slot by the fault injector (crash simulation)."""


# ---------------------------------------------------------------------------
# Deterministic fault injection
# ---------------------------------------------------------------------------

class FaultInjector:
    """Seeded, deterministic fault source shared by both executors.

    Faults are decided per *slot execution* (one call of a slot's work
    function).  Three trigger mechanisms compose:

      * ``crash_prob`` / ``stall_prob`` — i.i.d. per-execution draws from
        a seeded ``numpy`` Generator (bit-for-bit reproducible);
      * ``device_crash_prob`` — per-device overrides, matched against the
        slot's physical device name (``"gpu0/q1"`` matches ``"gpu0"``);
      * ``crash_on_call`` / ``stall_on_call`` — exact Nth-call triggers:
        device name -> collection of 1-based call indices that fault.
        The per-device call counter survives retries, so "fail call 1"
        kills only the first attempt and lets the retry pass.

    ``stall_seconds`` is how long an injected stall blocks (real
    executor) or how much simulated time it adds (simulator) — size it
    above the watchdog deadline to exercise :class:`SlotTimeout`.
    """

    def __init__(self, *, seed: int = 0, crash_prob: float = 0.0,
                 stall_prob: float = 0.0, stall_seconds: float = 1.0,
                 device_crash_prob: Optional[Dict[str, float]] = None,
                 crash_on_call: Optional[Dict[str, Sequence[int]]] = None,
                 stall_on_call: Optional[Dict[str, Sequence[int]]] = None):
        self.rng = np.random.default_rng(seed)
        self.crash_prob = crash_prob
        self.stall_prob = stall_prob
        self.stall_seconds = stall_seconds
        self.device_crash_prob = dict(device_crash_prob or {})
        self.crash_on_call = {k: set(v) for k, v in
                              (crash_on_call or {}).items()}
        self.stall_on_call = {k: set(v) for k, v in
                              (stall_on_call or {}).items()}
        self.calls: Dict[str, int] = {}
        self.injected: List[Tuple[str, str, int]] = []   # (kind, device, call)
        self._lock = threading.Lock()   # slots run concurrently (threaded
        #                                 executor); counters must not race

    @staticmethod
    def _base(device: str) -> str:
        return device.split("/")[0]

    def decide(self, device: str) -> Optional[str]:
        """Fault decision for one slot execution: None|'crash'|'stall'.

        Nth-call triggers are deterministic under any executor; the
        probability draws are additionally bit-for-bit reproducible on the
        (single-threaded) simulator, where the call order is fixed.
        """
        with self._lock:
            return self._decide_locked(device)

    def _decide_locked(self, device: str) -> Optional[str]:
        base = self._base(device)
        n = self.calls.get(base, 0) + 1
        self.calls[base] = n
        kind: Optional[str] = None
        if n in self.crash_on_call.get(base, ()) or \
                n in self.crash_on_call.get(device, ()):
            kind = "crash"
        elif n in self.stall_on_call.get(base, ()) or \
                n in self.stall_on_call.get(device, ()):
            kind = "stall"
        else:
            p_crash = self.device_crash_prob.get(
                base, self.device_crash_prob.get(device, self.crash_prob))
            draw = float(self.rng.random())
            if draw < p_crash:
                kind = "crash"
            elif self.stall_prob and draw < p_crash + self.stall_prob:
                kind = "stall"
        if kind:
            self.injected.append((kind, device, n))
        return kind


# ---------------------------------------------------------------------------
# Retry / repartition policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Retry ladder shared by the threaded executor and the simulator.

    ``watchdog_multiple`` scales ``profile.best_time`` into a per-slot
    deadline (a slot taking > multiple x best-known time is declared
    hung).  When no best time is known yet, ``default_deadline`` applies
    (``None`` disables the watchdog for that run).  ``max_attempts``
    bounds the re-partition/retry rounds before :class:`ExecutionError`.
    """

    max_attempts: int = 3
    watchdog_multiple: float = 8.0
    min_deadline: float = 0.25          # floor — best_time can be tiny
    default_deadline: Optional[float] = None

    def deadline(self, best_time: float) -> Optional[float]:
        if best_time is not None and math.isfinite(best_time) \
                and best_time > 0:
            return max(self.watchdog_multiple * best_time, self.min_deadline)
        return self.default_deadline


def split_units(units: int, n_ways: int) -> List[int]:
    """Largest-remainder even split of a lost slice's domain units."""
    if n_ways <= 0:
        raise ValueError("no surviving slots to split across")
    base, rem = divmod(units, n_ways)
    return [base + (1 if i < rem else 0) for i in range(n_ways)]


# ---------------------------------------------------------------------------
# Device health tracking (Scheduler side)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _HealthEntry:
    consecutive_failures: int = 0
    quarantined_at: int = -1            # run index; -1 = healthy
    total_failures: int = 0
    total_successes: int = 0


class DeviceHealth:
    """Consecutive-failure quarantine with probationary reinstatement.

    A device accumulating ``quarantine_after`` consecutive slot faults is
    quarantined: the Scheduler rebuilds ``_slots`` without it (graceful
    degradation to CPU-only or GPU-only).  After ``probe_after`` further
    scheduled runs the device becomes *probationary*: it re-enters the
    slot list with a capped share (``probe_share``); one clean run fully
    reinstates it, another fault re-quarantines it and restarts the
    probation clock.  Failed-run statistics never feed the load balancer
    or the KB, so fault noise cannot corrupt learned profiles.
    """

    def __init__(self, *, quarantine_after: int = 2, probe_after: int = 3,
                 probe_share: float = 0.05):
        self.quarantine_after = quarantine_after
        self.probe_after = probe_after
        self.probe_share = probe_share
        self.runs = 0                   # scheduled-run clock
        self.version = 0                # bumped on quarantine/reinstatement
        self.telemetry = NULL_TELEMETRY
        self._entries: Dict[str, _HealthEntry] = {}
        # concurrent graph nodes observe health from multiple threads;
        # RLock keeps the read-modify-write transitions atomic
        self._lock = threading.RLock()

    def _entry(self, device: str) -> _HealthEntry:
        return self._entries.setdefault(device, _HealthEntry())

    # -- observation ---------------------------------------------------------
    def tick(self) -> None:
        """Advance the run clock (one scheduled execution)."""
        with self._lock:
            self.runs += 1

    def record_failure(self, device: str) -> bool:
        """Register one slot fault; True if the device is now quarantined.

        A quarantine transition is never silent: it is emitted as a
        warning-level event through the telemetry logging bridge (which
        forwards to the ``repro.telemetry`` stdlib logger even when
        telemetry is disabled), carrying the device identity and the
        consecutive-failure count that tripped the threshold."""
        with self._lock:
            e = self._entry(device)
            e.consecutive_failures += 1
            e.total_failures += 1
            self.telemetry.metrics.counter("device_failures_total",
                                           device=device).inc()
            if e.consecutive_failures >= self.quarantine_after:
                if e.quarantined_at < 0:
                    self.version += 1   # slot set changed: plans go stale
                    self.telemetry.metrics.counter("quarantines_total").inc()
                    self.telemetry.events.emit(
                        "health.quarantined", level="warning",
                        message=f"device {device} quarantined after "
                                f"{e.consecutive_failures} "
                                "consecutive failures",
                        device=device,
                        consecutive_failures=e.consecutive_failures,
                        run=self.runs)
                e.quarantined_at = self.runs
                return True
            return False

    def record_success(self, device: str) -> None:
        with self._lock:
            e = self._entry(device)
            was_quarantined = e.quarantined_at >= 0
            e.consecutive_failures = 0
            e.total_successes += 1
            if was_quarantined:
                self.version += 1       # reinstatement: slot set changed
                self.telemetry.metrics.counter("reinstatements_total").inc()
                self.telemetry.events.emit(
                    "health.reinstated", level="warning",
                    message=f"device {device} reinstated after a clean "
                            "probe run",
                    device=device, run=self.runs,
                    total_failures=e.total_failures)
            e.quarantined_at = -1       # clean probe run -> reinstated

    # -- queries -------------------------------------------------------------
    def is_quarantined(self, device: str) -> bool:
        with self._lock:
            e = self._entries.get(device)
            return bool(e and e.quarantined_at >= 0)

    def is_probing(self, device: str) -> bool:
        """Quarantined device due for a probationary probe run."""
        with self._lock:
            e = self._entries.get(device)
            return bool(e and e.quarantined_at >= 0
                        and self.runs - e.quarantined_at >= self.probe_after)

    def usable(self, device: str) -> bool:
        """Device may receive work this run (healthy or probing)."""
        with self._lock:
            return not self.is_quarantined(device) or self.is_probing(device)

    def quarantined(self) -> Set[str]:
        with self._lock:
            return {d for d, e in self._entries.items()
                    if e.quarantined_at >= 0}

    def snapshot(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            return {d: {"consecutive_failures": e.consecutive_failures,
                        "total_failures": e.total_failures,
                        "total_successes": e.total_successes,
                        "quarantined": int(e.quarantined_at >= 0)}
                    for d, e in self._entries.items()}
