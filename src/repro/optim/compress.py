"""Gradient compression: int8 quantisation with error feedback.

Collective-term reducer for DP-bound cells (EXPERIMENTS.md §Perf).  The
gradient all-reduce moves ``4·P`` bytes/step in f32; quantising to int8
with a per-tensor scale cuts the wire bytes 4x at the cost of quantisation
noise, which error feedback (Seide et al., 1-bit SGD lineage) re-injects
next step so the *accumulated* update stays unbiased.

Exactness scheme: the scale is agreed globally first (a pmax over the
shards — 4 bytes per tensor), every shard quantises with the *same* scale,
and the int8 tree is psum'd in int32.  ``mean = q_sum * scale / n`` is then
the exact mean of the quantised per-shard gradients; each shard's
quantisation error stays in its local error-feedback state.

Usage inside a shard_map'd gradient sync (explicit-collective DP path —
see ``repro.runtime.train.sync_grads_int8``):

    scale = shared_scale(grads, state, axis='data')
    q, st = compress_gradients(grads, state, scale)
    q_sum = jax.tree.map(lambda x: jax.lax.psum(x.astype(jnp.int32),
                                                'data'), q)
    grads = decompress_sum(q_sum, scale, n_shards)
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any          # residual feedback tree (f32, grads structure)


def init_compression(grads_like: Any) -> CompressionState:
    return CompressionState(error=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def quantize_int8(x: jax.Array, scale: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8. Returns (q, scale); x ≈ q * scale."""
    if scale is None:
        amax = jnp.max(jnp.abs(x))
        scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, jnp.asarray(scale, jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def shared_scale(grads: Any, state: CompressionState,
                 axis: Optional[str] = None) -> Any:
    """Per-tensor scale tree, agreed across ``axis`` when given (pmax)."""
    def one(g, e):
        amax = jnp.max(jnp.abs(g.astype(jnp.float32) + e))
        if axis is not None:
            amax = jax.lax.pmax(amax, axis)
        return jnp.maximum(amax, 1e-30) / 127.0

    return jax.tree.map(one, grads, state.error)


def compress_gradients(grads: Any, state: CompressionState, scales: Any
                       ) -> Tuple[Any, CompressionState]:
    """Quantise (grads + carried error) with the given per-tensor scales."""
    def one(g, e, s):
        corrected = g.astype(jnp.float32) + e
        q, _ = quantize_int8(corrected, s)
        err = corrected - dequantize_int8(q, s)
        return q, err

    out = jax.tree.map(one, grads, state.error, scales)
    q = jax.tree.map(lambda t: t[0], out,
                     is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return q, CompressionState(error=err)


def decompress_sum(q_sum: Any, scales: Any, n_shards: int) -> Any:
    """Decode a psum of same-scale int8 grads into the mean gradient."""
    return jax.tree.map(
        lambda qs, s: qs.astype(jnp.float32) * (s / n_shards),
        q_sum, scales)
