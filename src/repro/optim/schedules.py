"""Learning-rate schedules: cosine and WSD (minicpm, arXiv:2404.06395).

All schedules are jnp-traceable ``step -> lr`` functions, usable both
inside jitted train steps and from host code.
"""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def f(step):
        return jnp.full((), lr, jnp.float32)
    return f


def linear_warmup(lr: float, warmup: int):
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        return lr * jnp.minimum(1.0, (s + 1) / max(warmup, 1))
    return f


def cosine_schedule(lr: float, warmup: int, total: int,
                    final_ratio: float = 0.1):
    """Linear warmup then cosine decay to final_ratio * lr."""
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        warm = lr * jnp.minimum(1.0, (s + 1) / max(warmup, 1))
        frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_ratio + (1 - final_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
        return jnp.where(s < warmup, warm, lr * cos)
    return f


def wsd_schedule(lr: float, warmup: int, stable: int, decay: int,
                 final_ratio: float = 0.01):
    """Warmup–Stable–Decay (minicpm): flat plateau, then a short
    exponential-style decay to ``final_ratio * lr`` over ``decay`` steps."""
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        warm = lr * jnp.minimum(1.0, (s + 1) / max(warmup, 1))
        in_decay = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = lr * final_ratio ** in_decay          # exp interp lr -> ratio*lr
        out = jnp.where(s < warmup, warm,
                        jnp.where(s < warmup + stable, lr, dec))
        return out
    return f
