"""AdamW with decoupled weight decay, global-norm clipping, f32 state.

Parameters may live in bf16 (the forward dtype); the optimizer keeps f32
first/second moments and applies the update in f32 before casting back, so
long trainings do not lose mantissa to bf16 accumulation.  The state is a
plain pytree and therefore checkpointable / shardable like any other — on
the production mesh the moments inherit the parameters' NamedSharding
(same tree structure), which is exactly ZeRO-1 when the params are FSDP-
sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


class OptState(NamedTuple):
    step: jax.Array          # int32 scalar
    m: Any                   # first moment (f32, params tree)
    v: Any                   # second moment (f32, params tree)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Union[float, Schedule] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0            # 0 disables clipping
    # decay mask: params whose path matches any of these substrings are
    # exempt from weight decay (norms, biases, scalar gains)
    no_decay: Tuple[str, ...] = ("norm", "scale", "bias", "dt_bias",
                                 "A_log", "D")

    def lr_at(self, step: jax.Array) -> jax.Array:
        if callable(self.lr):
            return jnp.asarray(self.lr(step), jnp.float32)
        return jnp.asarray(self.lr, jnp.float32)


class AdamW:
    """init/update pair closed over a config (optax-style, dependency-free)."""

    def __init__(self, config: AdamWConfig = AdamWConfig()):
        self.config = config

    # -- state ---------------------------------------------------------------
    def init(self, params: Any) -> OptState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                        v=jax.tree.map(jnp.copy, zeros))

    # -- decay mask ------------------------------------------------------------
    def _decay_mask(self, params: Any) -> Any:
        paths = jax.tree_util.tree_flatten_with_path(params)[0]

        def decayed(path) -> float:
            key = "/".join(str(getattr(p, "key", p)) for p in path).lower()
            return 0.0 if any(s in key for s in self.config.no_decay) else 1.0

        mask = [decayed(p) for p, _ in paths]
        treedef = jax.tree.structure(params)
        return jax.tree.unflatten(treedef, mask)

    # -- update ----------------------------------------------------------------
    def update(self, grads: Any, state: OptState, params: Any
               ) -> Tuple[Any, OptState, jax.Array]:
        """Returns (new_params, new_state, grad_norm)."""
        c = self.config
        step = state.step + 1
        gnorm = global_norm(grads)
        if c.grad_clip and c.grad_clip > 0:
            scale = jnp.minimum(1.0, c.grad_clip / jnp.maximum(gnorm, 1e-12))
        else:
            scale = jnp.ones((), jnp.float32)

        lr = c.lr_at(step)
        b1c = 1.0 - c.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - c.b2 ** step.astype(jnp.float32)
        mask = self._decay_mask(params)

        def upd(g, m, v, p, wd):
            g = g.astype(jnp.float32) * scale
            m_new = c.b1 * m + (1 - c.b1) * g
            v_new = c.b2 * v + (1 - c.b2) * g * g
            mhat = m_new / b1c
            vhat = v_new / b2c
            delta = mhat / (jnp.sqrt(vhat) + c.eps)
            delta = delta + c.weight_decay * wd * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * delta
            return p_new.astype(p.dtype), m_new, v_new

        out = jax.tree.map(upd, grads, state.m, state.v, params, mask)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, OptState(step=step, m=new_m, v=new_v), gnorm
