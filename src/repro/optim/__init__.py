"""Optimizer substrate (pure JAX — no optax on this container)."""
from repro.optim.adamw import AdamW, AdamWConfig, OptState, global_norm
from repro.optim.schedules import (constant, cosine_schedule, linear_warmup,
                                   wsd_schedule)
from repro.optim.compress import (CompressionState, compress_gradients,
                                  decompress_sum, init_compression,
                                  quantize_int8, dequantize_int8,
                                  shared_scale)

__all__ = [n for n in dir() if not n.startswith("_")]
