"""Pallas Segmentation — the paper's 3-D Map benchmark.

Gray-scale volume -> {black, gray, white} by two thresholds.  The
elementary partitioning unit is one (D1 x D2) plane (paper Sec. 4:
"partitioning can be performed only over the last [dimension]"), so the
block is a whole plane and the grid walks dim 2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _seg_kernel(vol_ref, o_ref, *, lo: float, hi: float):
    v = vol_ref[...]
    out = jnp.where(v < lo, 0.0, jnp.where(v > hi, 255.0, 128.0))
    o_ref[...] = out.astype(o_ref.dtype)


def segmentation(vol: jax.Array, *, lo: float = 85.0, hi: float = 170.0,
                 interpret: bool = False) -> jax.Array:
    """vol (D1, D2, D3) f32 -> segmented volume (plane-partitioned)."""
    D1, D2, D3 = vol.shape
    kernel = functools.partial(_seg_kernel, lo=lo, hi=hi)
    return pl.pallas_call(
        kernel,
        grid=(D3,),
        in_specs=[pl.BlockSpec((D1, D2, 1), lambda i: (0, 0, i))],
        out_specs=pl.BlockSpec((D1, D2, 1), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((D1, D2, D3), vol.dtype),
        interpret=interpret,
    )(vol)
