"""Pallas N-Body direct-sum — the paper's Loop benchmark.

Each body interacts with every other: the dataset is COPY-mode (fully
replicated, paper Sec. 4), work is partitioned at *body* granularity.
Grid: (n_i_blocks, n_j_blocks), j innermost with an f32 VMEM accumulator;
i-bodies stay resident for a whole j sweep (the classic O(N²) tiling —
on TPU the j tile streams through the VPU at 8x128 lanes).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SOFTENING = 1e-3


def _nbody_kernel(pos_i_ref, mass_all_ref, pos_all_ref, acc_out_ref,
                  acc_ref, *, block_j: int):
    jb = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(jb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pi = pos_i_ref[...]                                  # (bi, 3)
    pj = pos_all_ref[...]                                # (bj, 3)
    mj = mass_all_ref[...]                               # (bj,)
    d = pj[None, :, :] - pi[:, None, :]                  # (bi, bj, 3)
    r2 = (d * d).sum(-1) + SOFTENING
    inv_r3 = jax.lax.rsqrt(r2) / r2
    acc_ref[...] += jnp.einsum("ij,ijk->ik", mj[None, :] * inv_r3, d)

    @pl.when(jb == nj - 1)
    def _emit():
        acc_out_ref[...] = acc_ref[...].astype(acc_out_ref.dtype)


def nbody_accelerations(pos: jax.Array, mass: jax.Array, *,
                        block_i: int = 256, block_j: int = 1024,
                        interpret: bool = False) -> jax.Array:
    """pos (N, 3) f32, mass (N,) f32 -> accelerations (N, 3)."""
    N = pos.shape[0]
    bi, bj = min(block_i, N), min(block_j, N)
    ni, nj = -(-N // bi), -(-N // bj)
    pad_i, pad_j = ni * bi - N, nj * bj - N
    pos_i = jnp.pad(pos, ((0, pad_i), (0, 0))) if pad_i else pos
    pos_j = jnp.pad(pos, ((0, pad_j), (0, 0))) if pad_j else pos
    mass_j = jnp.pad(mass, (0, pad_j)) if pad_j else mass  # padded m=0: no force

    kernel = functools.partial(_nbody_kernel, block_j=bj)
    acc = pl.pallas_call(
        kernel,
        grid=(ni, nj),
        in_specs=[
            pl.BlockSpec((bi, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((bj,), lambda i, j: (j,)),
            pl.BlockSpec((bj, 3), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bi, 3), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ni * bi, 3), pos.dtype),
        scratch_shapes=[pltpu.VMEM((bi, 3), jnp.float32)],
        interpret=interpret,
    )(pos_i, mass_j, pos_j)
    return acc[:N]


def nbody_step(pos: jax.Array, vel: jax.Array, mass: jax.Array,
               dt: float = 0.01, *, interpret: bool = False
               ) -> Tuple[jax.Array, jax.Array]:
    """One leapfrog step (the paper's Loop body)."""
    acc = nbody_accelerations(pos, mass, interpret=interpret)
    vel = vel + acc * dt
    return pos + vel * dt, vel
