"""Pallas TPU kernel for the Mamba2 SSD chunk recurrence.

One grid step processes one (batch, chunk) cell: the within-chunk
"attention-like" part (three MXU matmuls over (Q, Q) / (Q, ds) tiles) and
the cross-chunk state update, with the (nh, ds, hd) state carried in VMEM
scratch across the chunk grid dimension — the Marrow *Loop* skeleton with
device-resident state (paper Sec. 3.1 stage 3), fused so the state never
round-trips to HBM between chunks.

Grid: (B, nc) with nc innermost.  VMEM per step:
``Q·(nh·hd + 2·ds + nh) + Q² + Q²·nh_blk + nh·ds·hd`` floats — at
(Q=256, nh=64, hd=64, ds=128) about 22 MiB, well under budget.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, B_ref, C_ref, A_ref, h0_ref,
                y_ref, hout_ref, h_ref, *, nheads: int, dstate: int,
                hdim: int, chunk: int):
    c = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)                # (Q, nh*hd)
    dt = dt_ref[0].astype(jnp.float32)              # (Q, nh)
    Bc = B_ref[0].astype(jnp.float32)               # (Q, ds)
    Cc = C_ref[0].astype(jnp.float32)               # (Q, ds)
    A = A_ref[...].astype(jnp.float32)              # (nh,)

    Q = chunk
    la = dt * A[None, :]                            # (Q, nh) log-decay
    cum = jnp.cumsum(la, axis=0)                    # (Q, nh)
    xh = x.reshape(Q, nheads, hdim)
    xdt = xh * dt[:, :, None]                       # (Q, nh, hd)

    # within-chunk: scores (Q,Q) via MXU; per-head decay applied blockwise
    scores = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    q_pos = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    k_pos = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tri = q_pos >= k_pos
    rel = cum[:, None, :] - cum[None, :, :]         # (Q, Q, nh)
    L = jnp.where(tri[:, :, None], jnp.exp(rel), 0.0)
    P = scores[:, :, None] * L                      # (Q, Q, nh)
    # y_diag[q,h,e] = sum_k P[q,k,h] * xdt[k,h,e]  (batched over h)
    y = jax.lax.dot_general(
        P.transpose(2, 0, 1), xdt.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)          # (nh, Q, hd)

    # carried-state contribution: y_off[q,h,e] = C[q,s]·h[h,s,e]·exp(cum)
    h = h_ref[...]                                   # (nh, ds, hd)
    y_off = jax.lax.dot_general(
        Cc, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (Q, nh, hd)
    y = y.transpose(1, 0, 2) + y_off * jnp.exp(cum)[:, :, None]
    y_ref[0] = y.reshape(Q, nheads * hdim).astype(y_ref.dtype)

    # state update: h = h * exp(cum[-1]) + sum_q B[q,s]·decay_to_end·xdt
    decay_end = jnp.exp(cum[Q - 1:Q, :] - cum)       # (Q, nh)
    w = xdt * decay_end[:, :, None]                  # (Q, nh, hd)
    S_c = jax.lax.dot_general(
        w.transpose(1, 0, 2), Bc, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (nh, hd, ds)
    h_ref[...] = (h * jnp.exp(cum[Q - 1])[:, None, None]
                  + S_c.transpose(0, 2, 1))

    @pl.when(c == nc - 1)
    def _emit_state():
        hout_ref[0] = h_ref[...]


def ssd_scan(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
             A: jax.Array, *, chunk: int,
             h0: Optional[jax.Array] = None,
             interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    x:  (B, S, nh*hd)  post-conv, pre-decay inner activations
    dt: (B, S, nh)     softplus'd step sizes (f32)
    B:  (B, S, ds), C: (B, S, ds)   post-conv projections
    A:  (nh,)          negative decay rates
    h0: (B, nh, ds, hd) initial state (zeros when None)

    Returns (y (B, S, nh*hd), h_final (B, nh, ds, hd)).
    S must be a multiple of ``chunk`` (callers pad).
    """
    Bsz, S, dih = x.shape
    nh = dt.shape[-1]
    hd = dih // nh
    ds = B.shape[-1]
    if S % chunk:
        raise ValueError(f"S={S} not a multiple of chunk={chunk}")
    nc = S // chunk
    if h0 is None:
        h0 = jnp.zeros((Bsz, nh, ds, hd), jnp.float32)

    kernel = functools.partial(_ssd_kernel, nheads=nh, dstate=ds, hdim=hd,
                               chunk=chunk)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(Bsz, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dih), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, nh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, c: (b, c, 0)),
            pl.BlockSpec((nh,), lambda b, c: (0,)),
            pl.BlockSpec((1, nh, ds, hd), lambda b, c: (b, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dih), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, nh, ds, hd), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, dih), x.dtype),
            jax.ShapeDtypeStruct((Bsz, nh, ds, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((nh, ds, hd), jnp.float32)],
        interpret=interpret,
    )(x, dt, B, C, A, h0)
    return y, h_final
