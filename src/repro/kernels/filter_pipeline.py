"""Pallas fused Filter Pipeline — the paper's Pipeline benchmark.

Gaussian-noise -> Solarize -> Mirror over an image, fused into one kernel
(the paper composes them as three SCT stages; the locality-aware
decomposition keeps the intermediate images on-device, which on TPU
collapses to VMEM-resident fusion).  The elementary partitioning unit is
the image *line* (paper Sec. 4) — blocks are whole rows, the work space
is processed two pixels per "thread" (lane pair), and Mirror needs the
full row in-block, which is exactly what epu=line guarantees.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _filter_kernel(img_ref, seed_ref, o_ref, *, noise_scale: float,
                   solarize_threshold: float, width: int):
    rows = img_ref[...]                               # (block_rows, W) f32
    # gaussian-ish noise: 2 uniform hashes -> irwin-hall(2) approximation
    r = pl.program_id(0)
    row_ids = jax.lax.broadcasted_iota(
        jnp.int32, rows.shape, 0) + r * rows.shape[0]
    col_ids = jax.lax.broadcasted_iota(jnp.int32, rows.shape, 1)
    seed = seed_ref[0]

    def hash01(salt):
        h = (row_ids * -1640531535 + col_ids * 40503 + seed * 69069
             + salt * 1013904223)
        h ^= h >> 13
        h = h * 1274126177
        h ^= h >> 16
        return (h & 0xFFFF).astype(jnp.float32) / 65535.0

    noise = (hash01(1) + hash01(2) - 1.0) * noise_scale
    v = jnp.clip(rows + noise, 0.0, 255.0)
    # solarize
    v = jnp.where(v > solarize_threshold, 255.0 - v, v)
    # mirror (full row resident: epu = line)
    o_ref[...] = v[:, ::-1].astype(o_ref.dtype)


def filter_pipeline(img: jax.Array, seed: int = 0, *,
                    noise_scale: float = 8.0,
                    solarize_threshold: float = 128.0,
                    block_rows: int = 64,
                    interpret: bool = False) -> jax.Array:
    """img (H, W) float32 in [0, 255] -> filtered (H, W)."""
    H, W = img.shape
    br = min(block_rows, H)
    nb = -(-H // br)
    pad = nb * br - H
    if pad:
        img = jnp.pad(img, ((0, pad), (0, 0)))
    kernel = functools.partial(_filter_kernel, noise_scale=noise_scale,
                               solarize_threshold=solarize_threshold,
                               width=W)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((br, W), lambda i: (i, 0)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * br, W), img.dtype),
        interpret=interpret,
    )(img, jnp.asarray([seed], jnp.int32))
    return out[:H]
