"""Pallas TPU flash attention: online-softmax tiling in VMEM.

TPU adaptation of the FlashAttention insight (the paper's "work-group
size" knob becomes the VMEM block shape): the S x S score matrix never
leaves VMEM — the kernel streams (block_q x block_k) tiles through the
MXU, carrying running max / sum / accumulator scratch across the k-grid
dimension.

Variants required by the assigned architectures:
  * GQA          — kv head index = q head // group size (BlockSpec index map)
  * causal       — additive mask from global block offsets
  * sliding window (mixtral, gemma2 local layers)
  * logit softcap (gemma2)

Grid: (batch, q_heads, num_q_blocks, num_k_blocks) — the k dimension is
innermost so the scratch accumulators are valid across its iterations;
block (1, 1, block_q, head_dim) of Q is resident for a whole k sweep.

Block-shape guidance (§Roofline): block_q/block_k multiples of 128 keep
the MXU systolic array full; VMEM footprint per step is
``block_q·hd + 2·block_k·hd + block_q·block_k`` floats (double-buffered
by the pipeline), comfortably under the ~128 MiB/core budget at
(512, 1024, hd=256).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  logit_cap: float, block_q: int, block_k: int,
                  kv_len: int):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)             # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)             # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)             # (bk, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if logit_cap and logit_cap > 0:
        s = logit_cap * jnp.tanh(s / logit_cap)

    iq = pl.program_id(2)
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # (bq,)
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    logit_cap: float = 0.0, scale: Optional[float] = None,
                    block_q: int = 512, block_k: int = 1024,
                    kv_len: Optional[int] = None,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, hd); k/v: (B, KV, Sk, hd) -> (B, H, Sq, hd).

    H must be a multiple of KV (GQA).  Sq/Sk are padded to block
    multiples internally; ``kv_len`` masks padded keys.
    """
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    if H % KV:
        raise ValueError(f"GQA needs H % KV == 0, got {H} % {KV}")
    G = H // KV
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    kvl = int(kv_len) if kv_len is not None else Sk

    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    nq = -(-Sq // bq)
    nk = -(-Sk // bk)
    qp, kp = nq * bq - Sq, nk * bk - Sk
    if qp:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, qp), (0, 0)))
    if kp:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, kp), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, kp), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, scale=sc, causal=causal, window=window,
        logit_cap=logit_cap, block_q=bq, block_k=bk, kv_len=kvl)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
