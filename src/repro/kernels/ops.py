"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute under ``interpret=True`` —
the kernel body runs in Python with real block indexing, which validates
BlockSpecs, grids, and scratch semantics; on TPU the same calls compile
to Mosaic.  ``use_pallas('auto')`` picks per-backend.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.filter_pipeline import filter_pipeline as _filter
from repro.kernels.moe_gemm import grouped_matmul as _gmm
from repro.kernels.nbody import nbody_accelerations as _nbody
from repro.kernels.nbody import nbody_step as _nbody_step
from repro.kernels.saxpy import saxpy as _saxpy
from repro.kernels.segmentation import segmentation as _seg
from repro.kernels.ssd_scan import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, **kw):
    """(B,H,S,hd) x (B,KV,S,hd) flash attention (GQA/causal/SWA/softcap)."""
    return _flash(q, k, v, interpret=_interpret(), **kw)


def flash_attention_bshd(q, k, v, **kw):
    """Model-layout adapter: (B,S,H,hd)/(B,S,KV,hd) in and out."""
    o = _flash(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
               v.transpose(0, 2, 1, 3), interpret=_interpret(), **kw)
    return o.transpose(0, 2, 1, 3)


def ssd_scan(x, dt, B, C, A, *, chunk: int, h0=None):
    return _ssd(x, dt, B, C, A, chunk=chunk, h0=h0,
                interpret=_interpret())


def grouped_matmul(x, w, **kw):
    return _gmm(x, w, interpret=_interpret(), **kw)


def saxpy(a, x, y, **kw):
    return _saxpy(jnp.asarray(a, x.dtype), x, y,
                  interpret=_interpret(), **kw)


def filter_pipeline(img, seed: int = 0, **kw):
    return _filter(img, seed, interpret=_interpret(), **kw)


def segmentation(vol, **kw):
    return _seg(vol, interpret=_interpret(), **kw)


def nbody_accelerations(pos, mass, **kw):
    return _nbody(pos, mass, interpret=_interpret(), **kw)


def nbody_step(pos, vel, mass, dt: float = 0.01):
    return _nbody_step(pos, vel, mass, dt, interpret=_interpret())
