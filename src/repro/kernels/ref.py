"""Pure-jnp oracles for every Pallas kernel (the allclose references).

Each function mirrors its kernel's contract exactly; tests sweep shapes
and dtypes asserting ``assert_allclose(kernel(interpret=True), ref)``.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  logit_cap: float = 0.0, scale: Optional[float] = None,
                  kv_len: Optional[int] = None) -> jax.Array:
    """q: (B,H,Sq,hd); k/v: (B,KV,Sk,hd).  Dense softmax reference."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    kf = jnp.repeat(k, G, axis=1)
    vf = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) * sc
    if logit_cap and logit_cap > 0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = kp < (Sk if kv_len is None else kv_len)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vf.astype(jnp.float32))
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

def ssd_scan_ref(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
                 A: jax.Array, *, chunk: int,
                 h0: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Sequential (per-token recurrence) oracle of the chunked kernel."""
    Bsz, S, dih = x.shape
    nh = dt.shape[-1]
    hd = dih // nh
    ds = B.shape[-1]
    xf = x.astype(jnp.float32).reshape(Bsz, S, nh, hd)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    h = (jnp.zeros((Bsz, nh, ds, hd), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))

    def step(h, inp):
        xt, dtt, Bt, Ct = inp                        # (B,nh,hd),(B,nh),(B,ds)
        a = jnp.exp(dtt * A[None, :])                # (B, nh)
        upd = jnp.einsum("bs,bh,bhe->bhse", Bt, dtt, xt)
        h = h * a[:, :, None, None] + upd
        y = jnp.einsum("bs,bhse->bhe", Ct, h)
        return h, y

    h_final, ys = jax.lax.scan(
        step, h, (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
                  Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2, 3).reshape(Bsz, S, dih)
    return y.astype(x.dtype), h_final


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------

def grouped_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# paper benchmark kernels
# ---------------------------------------------------------------------------

def saxpy_ref(a: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    return a * x + y


def filter_pipeline_ref(img: jax.Array, seed: int = 0, *,
                        noise_scale: float = 8.0,
                        solarize_threshold: float = 128.0) -> jax.Array:
    """Mirrors the kernel's hash-based noise exactly (same LCG)."""
    H, W = img.shape
    row = jnp.arange(H, dtype=jnp.int32)[:, None] * jnp.ones(
        (1, W), jnp.int32)
    col = jnp.arange(W, dtype=jnp.int32)[None, :] * jnp.ones(
        (H, 1), jnp.int32)

    def hash01(salt):
        h = (row * -1640531535 + col * 40503 + seed * 69069
             + salt * 1013904223)
        h ^= h >> 13
        h = h * 1274126177
        h ^= h >> 16
        return (h & 0xFFFF).astype(jnp.float32) / 65535.0

    noise = (hash01(1) + hash01(2) - 1.0) * noise_scale
    v = jnp.clip(img + noise, 0.0, 255.0)
    v = jnp.where(v > solarize_threshold, 255.0 - v, v)
    return v[:, ::-1].astype(img.dtype)


def segmentation_ref(vol: jax.Array, *, lo: float = 85.0,
                     hi: float = 170.0) -> jax.Array:
    return jnp.where(vol < lo, 0.0,
                     jnp.where(vol > hi, 255.0, 128.0)).astype(vol.dtype)


def nbody_ref(pos: jax.Array, mass: jax.Array,
              softening: float = 1e-3) -> jax.Array:
    d = pos[None, :, :] - pos[:, None, :]            # (N, N, 3)
    r2 = (d * d).sum(-1) + softening
    inv_r3 = jax.lax.rsqrt(r2) / r2
    return jnp.einsum("ij,ijk->ik", mass[None, :] * inv_r3, d)
