"""Pallas TPU kernels for the compute hot spots (+ jnp oracles in ref.py).

Layout:
  flash_attention.py  pl.pallas_call online-softmax attention (GQA/SWA/cap)
  ssd_scan.py         Mamba2 SSD chunk recurrence (state in VMEM scratch)
  moe_gemm.py         grouped expert GEMM (MegaBlocks-style)
  saxpy.py, filter_pipeline.py, segmentation.py, nbody.py
                      the paper's own benchmark suite (Sec. 4)
  ops.py              jit'd wrappers (interpret=True off-TPU)
  ref.py              pure-jnp oracles for allclose tests
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
