"""Pallas TPU grouped GEMM for MoE expert FFNs (MegaBlocks-style).

Computes ``y[e] = x[e] @ w[e]`` for E experts over capacity-padded token
buffers — one kernel launch instead of E small GEMMs, so the MXU stays
fed even when experts are narrow (granite: d_ff=512 per expert).

Grid: (E, nC, nF, nK) — contraction (d) innermost with an f32 VMEM
accumulator, so arbitrarily large d streams through fixed VMEM:
``block_c·block_d + block_d·block_f + block_c·block_f`` floats/step.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(x_ref, w_ref, y_ref, acc_ref):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _emit():
        y_ref[0] = acc_ref[...].astype(y_ref.dtype)


def grouped_matmul(x: jax.Array, w: jax.Array, *,
                   block_c: int = 128, block_f: int = 512,
                   block_d: int = 512,
                   interpret: bool = False) -> jax.Array:
    """x: (E, C, d), w: (E, d, f) -> (E, C, f)."""
    E, C, d = x.shape
    _, _, f = w.shape
    bc, bf, bd = min(block_c, C), min(block_f, f), min(block_d, d)
    nc, nf, nk = -(-C // bc), -(-f // bf), -(-d // bd)
    cp, fp, dp = nc * bc - C, nf * bf - f, nk * bd - d
    if cp or dp:
        x = jnp.pad(x, ((0, 0), (0, cp), (0, dp)))
    if dp or fp:
        w = jnp.pad(w, ((0, 0), (0, dp), (0, fp)))

    y = pl.pallas_call(
        _gemm_kernel,
        grid=(E, nc, nf, nk),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, ic, jf, ik: (e, ic, ik)),
            pl.BlockSpec((1, bd, bf), lambda e, ic, jf, ik: (e, ik, jf)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf),
                               lambda e, ic, jf, ik: (e, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((E, nc * bc, nf * bf), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return y[:, :C, :f]
