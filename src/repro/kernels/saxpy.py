"""Pallas saxpy — the paper's Map benchmark (BLAS single-precision
a*x + y).  Embarrassingly parallel, epu=1; the VPU analogue of the
paper's per-thread work is the (8, 128)-lane block."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024          # 8 sublanes x 128 lanes


def _saxpy_kernel(a_ref, x_ref, y_ref, o_ref):
    o_ref[...] = a_ref[0] * x_ref[...] + y_ref[...]


def saxpy(a: jax.Array, x: jax.Array, y: jax.Array, *,
          block: int = 1 << 16, interpret: bool = False) -> jax.Array:
    """a scalar, x/y (N,) -> a*x + y."""
    n = x.shape[0]
    b = min(block, max(n, LANES))
    nb = -(-n // b)
    pad = nb * b - n
    if pad:
        x = jnp.pad(x, (0, pad))
        y = jnp.pad(y, (0, pad))
    out = pl.pallas_call(
        _saxpy_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * b,), x.dtype),
        interpret=interpret,
    )(a.reshape(1), x, y)
    return out[:n]
