"""Roofline terms from compiled dry-run artifacts (TPU v5e targets).

    compute    = FLOPs_per_chip / peak_FLOP/s
    memory     = HBM_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

The analyzer (:mod:`repro.launch.hlo_analysis`) walks the *per-partition*
HLO module, so all quantities are already per-chip; the assignment's
``X_global / (chips × bw)`` formulation is identical.

Hardware constants (assignment): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

#: MODEL_FLOPS multiplier per step kind: train = fwd+bwd (6ND),
#: prefill/decode = fwd only (2ND); N = active params, D = tokens.
KIND_FACTOR = {"train": 6.0, "prefill": 2.0, "decode": 2.0}


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float           # global useful FLOPs (6·N·D or 2·N·D)
    hlo_flops: float             # global compiled FLOPs (per_chip × chips)
    chips: int

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline (no-overlap lower bound = max of the three terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Model-FLOPs utilisation at the roofline step time (the score):
        useful FLOPs / (chips × peak × step_time)."""
        denom = self.chips * PEAK_FLOPS * self.step_time_s
        return self.model_flops / denom if denom else 0.0

    def to_json(self) -> Dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
            "useful_fraction": self.useful_fraction,
            "roofline_fraction": self.roofline_fraction,
            "chips": self.chips,
        }


def roofline(*, per_chip_flops: float, per_chip_hbm_bytes: float,
             per_chip_collective_bytes: float, chips: int,
             active_params: float, tokens: float, kind: str) -> Roofline:
    model_flops = KIND_FACTOR[kind] * active_params * tokens
    return Roofline(
        compute_s=per_chip_flops / PEAK_FLOPS,
        memory_s=per_chip_hbm_bytes / HBM_BW,
        collective_s=per_chip_collective_bytes / LINK_BW,
        model_flops=model_flops,
        hlo_flops=per_chip_flops * chips,
        chips=chips,
    )
