"""Launch layer: production meshes, dry-run, train/serve drivers.

NOTE: ``repro.launch.dryrun`` sets ``XLA_FLAGS`` at import time (512
placeholder devices) and must only be imported as a program entry point —
it is deliberately NOT re-exported here.
"""
from repro.launch.cells import CellConfig, cell_runtime, size_class
from repro.launch.mesh import (dp_axes, make_host_mesh, make_production_mesh,
                               mesh_chips)
from repro.launch.roofline import (HBM_BW, LINK_BW, PEAK_FLOPS, Roofline,
                                   roofline)

__all__ = [n for n in dir() if not n.startswith("_")]
