"""Training launcher — the end-to-end driver (``--arch <id>``).

Runs *real* steps on whatever devices exist (the production path is the
same code under a (16, 16) mesh; this container runs the reduced configs
on CPU), with the full fault-tolerance loop:

  * deterministic stateless data (restart-safe by construction),
  * atomic async checkpoints every ``--ckpt-every`` steps, keep-K,
  * automatic restore-from-latest on start (preemption recovery),
  * per-arch LR recipe (minicpm: WSD; others: cosine),
  * optional int8+error-feedback gradient sync (``--compress``).

Usage:
    python -m repro.launch.train --arch gemma2-2b --smoke --steps 50
    python -m repro.launch.train --arch minicpm-2b --smoke --resume
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke
from repro.data import DataConfig, batch_at
from repro.launch.mesh import make_host_mesh
from repro.models import init_tree, model_defs
from repro.optim import AdamW, AdamWConfig, cosine_schedule, wsd_schedule
from repro.runtime import (RuntimeConfig, init_state, make_dp_train_step_int8,
                           make_train_step)


def build_optimizer(cfg, lr: float, steps: int) -> AdamW:
    if cfg.lr_schedule == "wsd":
        sched = wsd_schedule(lr, warmup=max(steps // 20, 1),
                             stable=int(steps * 0.7),
                             decay=max(int(steps * 0.25), 1))
    else:
        sched = cosine_schedule(lr, warmup=max(steps // 20, 1), total=steps)
    return AdamW(AdamWConfig(lr=sched))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="int8 + error-feedback DP gradient sync")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    print(f"[train] arch={cfg.arch} params={cfg.param_count()/1e6:.1f}M "
          f"schedule={cfg.lr_schedule}")

    opt = build_optimizer(cfg, args.lr, args.steps)
    rt = RuntimeConfig(microbatches=args.microbatches, remat=args.remat,
                       loss_chunks=1, aux_weight=0.01)
    params = init_tree(jax.random.PRNGKey(args.seed), model_defs(cfg))
    state = init_state(params, opt, compress=args.compress)

    if args.compress:
        mesh = make_host_mesh(("data",))
        step_fn = jax.jit(make_dp_train_step_int8(cfg, opt, rt, mesh),
                          donate_argnums=(0,))
    else:
        step_fn = jax.jit(make_train_step(cfg, opt, rt), donate_argnums=(0,))

    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                    global_batch=args.batch, seed=args.seed)

    start = 0
    mgr: Optional[CheckpointManager] = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=args.keep)
        if args.resume:
            got = mgr.restore_latest(jax.device_get(state))
            if got is not None:
                tree, meta = got
                state = jax.tree.map(jnp.asarray, tree)
                start = meta.step
                print(f"[train] resumed from step {start}")

    extras = {}
    if cfg.enc_dec:
        extras["frames"] = jax.random.normal(
            jax.random.PRNGKey(7), (args.batch, cfg.enc_frames, cfg.d_model),
            jnp.bfloat16)
    elif cfg.frontend_positions:
        extras["frontend_embeds"] = jax.random.normal(
            jax.random.PRNGKey(7),
            (args.batch, cfg.frontend_positions, cfg.d_model), jnp.bfloat16)

    t0 = time.time()
    tokens_per_step = args.batch * args.seq_len
    for step in range(start, args.steps):
        batch = dict(batch_at(dc, step))
        batch.update(extras)
        state, metrics = step_fn(state, batch)
        if (step + 1) % args.log_every == 0 or step == args.steps - 1:
            m = jax.device_get(metrics)
            dt = time.time() - t0
            tps = tokens_per_step * (step + 1 - start) / max(dt, 1e-9)
            print(f"step {step + 1:5d} loss={float(m['loss']):.4f} "
                  f"aux={float(m['aux_loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"lr={float(m['lr']):.2e} tok/s={tps:,.0f}")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state, payload={"data_step": step + 1})
    if mgr:
        mgr.save(args.steps, state, payload={"data_step": args.steps},
                 blocking=True)
    print(f"[train] done in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
