"""Serving launcher — batched request serving (``--arch <id>``).

Continuous slot-based batching over a synthetic request stream: requests
join mid-flight as slots free up, the decode batch is shape-stable (no
recompiles), throughput is reported as decoded tokens/s.

Usage:
    python -m repro.launch.serve --arch mamba2-1.3b --smoke \
        --requests 12 --slots 4 --max-new 24
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models import init_tree, model_defs
from repro.runtime import ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.enc_dec:
        raise SystemExit(f"{cfg.arch}: enc-dec serving needs audio frames; "
                         "use examples/serve_llm.py patterns instead")
    print(f"[serve] arch={cfg.arch} slots={args.slots} "
          f"capacity={args.capacity}")
    params = init_tree(jax.random.PRNGKey(args.seed), model_defs(cfg))
    engine = ServeEngine(cfg, params, slots=args.slots,
                         capacity=args.capacity,
                         temperature=args.temperature, seed=args.seed)

    rng = np.random.default_rng(args.seed)
    for r in range(args.requests):
        plen = int(rng.integers(4, args.prompt_len + 1))
        prompt = rng.integers(0, cfg.vocab, plen).tolist()
        engine.submit(prompt, max_new=args.max_new)

    t0 = time.time()
    steps = 0
    while engine.queue or any(s is not None for s in engine.active):
        engine.step()
        steps += 1
        if steps > 10_000:
            raise RuntimeError("serve loop did not converge")
    dt = time.time() - t0
    done = engine.finished
    toks = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s, {steps} engine steps)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4]} "
              f"out[:8]={r.out[:8]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
