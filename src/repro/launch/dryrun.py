import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution configuration is coherent without
real hardware: for the single-pod (16, 16) mesh and the 2-pod
(2, 16, 16) mesh, every cell's step function must
``.lower().compile()`` under the production shardings, its
``memory_analysis()`` must fit the 16 GiB/chip HBM budget, and its HLO is
analysed (loop-aware) into the three roofline terms.

Artifacts: one JSON per cell under ``experiments/dryrun/<mesh>/``,
consumed by EXPERIMENTS.md §Dry-run/§Roofline and benchmarks/roofline.py.

Usage:
    python -m repro.launch.dryrun --mesh single --arch gemma2-2b \
        --shape train_4k
    python -m repro.launch.dryrun --mesh both            # all cells
    python -m repro.launch.dryrun --mesh single --set microbatches=8
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import contextlib

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.moe import moe_mesh

from repro.configs import (SHAPES, applicable, arch_names, get_config,
                           input_specs)
from repro.configs.shapes import ShapeSpec
from repro.launch.cells import CellConfig, cell_runtime
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import dp_axes, make_production_mesh, mesh_chips
from repro.launch.roofline import roofline
from repro.models import (ModelConfig, default_rules, model_defs,
                          sharding_tree, shape_tree)
from repro.models.lm import cache_defs, cache_dtype, decode_step, prefill
from repro.models.sharding import Rules, sharding_for
from repro.optim import AdamW, AdamWConfig
from repro.runtime import RuntimeConfig, TrainState, make_train_step
from repro.optim.adamw import OptState

HBM_PER_CHIP = 16 * 1024 ** 3          # v5e


# ---------------------------------------------------------------------------
# Cell assembly: (step fn, arg structs, arg shardings, donate)
# ---------------------------------------------------------------------------

def _batch_sharding(mesh: Mesh, rules: Rules, struct: jax.ShapeDtypeStruct,
                    leading: str = "batch") -> NamedSharding:
    logical = (leading,) + (None,) * (len(struct.shape) - 1)
    return sharding_for(struct.shape, logical, mesh, rules)


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
               cell: CellConfig):
    """Returns (fn, args, in_shardings, donate_argnums, out_shardings)."""
    rules = default_rules(mesh, fsdp=cell.fsdp, seq_shard=cell.seq_shard)
    defs = model_defs(cfg)
    params_struct = shape_tree(defs)                       # bf16
    params_shard = sharding_tree(defs, mesh, rules)
    data = input_specs(cfg, shape)
    data_shard = {k: _batch_sharding(mesh, rules, v)
                  for k, v in data.items() if v.shape}
    rep = NamedSharding(mesh, P())

    dp = dp_axes(mesh)
    # (batch, seq, embed): batch over dp; optionally seq over model (SP)
    act_spec = (P(dp, "model") if cell.act_seq_shard else P(dp))
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if shape.kind == "train":
        # clamp microbatches so each microbatch's batch dim still tiles
        # the dp axes (B/M % n_dp == 0); otherwise GSPMD replicates the
        # whole residual stream
        mb = max(1, min(cell.microbatches, shape.global_batch // n_dp))
        while shape.global_batch % mb or (shape.global_batch // mb) % n_dp:
            mb -= 1
        cell = cell.replace(microbatches=mb)
        rt = RuntimeConfig(microbatches=cell.microbatches, remat=cell.remat,
                           remat_group=cell.remat_group,
                           remat_inner=cell.remat_inner,
                           loss_chunks=cell.loss_chunks, data_axes=dp,
                           act_spec=act_spec)
        opt = AdamW(AdamWConfig())
        step = make_train_step(cfg, opt, rt)
        f32 = lambda t: jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t)
        state = TrainState(
            params=params_struct,
            opt=OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                         m=f32(params_struct), v=f32(params_struct)),
            compression=None)
        state_shard = TrainState(
            params=params_shard,
            opt=OptState(step=rep, m=params_shard, v=params_shard),
            compression=None)
        batch = dict(data)
        batch_shard = {k: data_shard.get(k, rep) for k in batch}
        args = (state, batch)
        in_shardings = (state_shard, batch_shard)
        out_shardings = (state_shard, None)
        donate = (0,) if cell.donate else ()
        return step, args, in_shardings, donate, out_shardings

    if shape.kind == "prefill":
        extra_names = [k for k in data if k != "tokens"]

        def prefill_fn(params, tokens, *extra):
            kw = dict(zip(extra_names, extra))
            return prefill(params, cfg, tokens, capacity=shape.seq_len,
                           act_spec=act_spec, **kw)

        args = (params_struct, data["tokens"]) + tuple(
            data[k] for k in extra_names)
        in_shardings = (params_shard, data_shard["tokens"]) + tuple(
            data_shard.get(k, rep) for k in extra_names)
        pdefs = cache_defs(cfg, shape.global_batch, shape.seq_len)
        out_shardings = (None, sharding_tree(pdefs, mesh, rules))
        return prefill_fn, args, in_shardings, (), out_shardings

    # decode
    cdefs = cache_defs(cfg, shape.global_batch, shape.seq_len)
    kv_dtype = (jnp.float8_e4m3fn if cell.cache_dtype == "f8"
                else jnp.bfloat16)

    def _cdtype(key):
        if key.startswith(("k", "v", "xk", "xv")):
            return kv_dtype
        return cache_dtype(key)

    cache_struct = {k: jax.ShapeDtypeStruct(d.shape, _cdtype(k))
                    for k, d in cdefs.items()}
    cache_shard = sharding_tree(cdefs, mesh, rules)

    def serve_step(params, cache, token, pos):
        return decode_step(params, cfg, cache, token, pos)

    args = (params_struct, cache_struct, data["token"], data["pos"])
    in_shardings = (params_shard, cache_shard,
                    data_shard.get("token", rep), rep)
    out_shardings = (None, cache_shard)
    donate = (1,) if cell.donate else ()
    return serve_step, args, in_shardings, donate, out_shardings


# ---------------------------------------------------------------------------
# One cell: lower, compile, analyse
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh: Mesh, mesh_name: str,
             overrides: Optional[Dict] = None,
             keep_hlo: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cell = cell_runtime(cfg, shape, overrides)
    chips = mesh_chips(mesh)
    t0 = time.time()
    fn, args, in_shardings, donate, out_shardings = build_cell(
        cfg, shape, mesh, cell)
    jitted = jax.jit(fn, in_shardings=in_shardings,
                     out_shardings=out_shardings,
                     donate_argnums=donate)
    moe_ctx = (moe_mesh(mesh, dp_axes(mesh), "model") if cfg.moe
               else contextlib.nullcontext())
    from repro.models.attention import attention_sp
    sp_ctx = (attention_sp("model") if cell.act_seq_shard
              else contextlib.nullcontext())
    with mesh, moe_ctx, sp_ctx:
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    ana = analyze(hlo)

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    rf = roofline(per_chip_flops=ana.flops,
                  per_chip_hbm_bytes=ana.hbm_bytes,
                  per_chip_collective_bytes=ana.total_collective_bytes,
                  chips=chips,
                  active_params=cfg.active_param_count(),
                  tokens=tokens, kind=shape.kind)

    peak_bytes = (mem.temp_size_in_bytes + mem.argument_size_in_bytes
                  + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    # the CPU dry-run backend has no native bf16: float-normalisation
    # materialises f32 copies of large bf16 buffers (absent on the TPU
    # target).  ``adjusted`` subtracts them (a lower bound — the converts
    # are not all simultaneously live), clamped at the argument+output
    # floor; the TPU-target peak lies in [adjusted, raw].
    floor = (mem.argument_size_in_bytes - mem.alias_size_in_bytes
             + mem.output_size_in_bytes)
    adjusted = max(peak_bytes - ana.legalization_bytes, floor)
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": chips, "cell": dataclasses_dict(cell),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_chip_bytes": peak_bytes,
            "cpu_legalization_bytes": ana.legalization_bytes,
            "adjusted_peak_per_chip_bytes": adjusted,
            "fits_16GiB": bool(adjusted < HBM_PER_CHIP),
        },
        "cost_analysis": {k: cost.get(k) for k in ("flops", "bytes accessed")
                          if k in cost},
        "hlo_analysis": ana.to_json(),
        "roofline": rf.to_json(),
    }
    if keep_hlo:
        record["hlo_text"] = hlo
    return record


def dataclasses_dict(cell: CellConfig) -> Dict:
    import dataclasses as dc
    return dc.asdict(cell)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def parse_overrides(pairs) -> Dict:
    out: Dict[str, Any] = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        if v in ("True", "False", "true", "false"):
            out[k] = v.lower() == "true"
        elif v in ("None", "null"):
            out[k] = None
        else:
            try:
                out[k] = int(v)
            except ValueError:
                out[k] = v
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--shape", default=None, help="one shape (default all)")
    ap.add_argument("--set", action="append", dest="overrides",
                    help="cell override knob=value (repeatable)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells with existing artifacts")
    ap.add_argument("--tag", default=None,
                    help="artifact suffix for hillclimb variants")
    args = ap.parse_args()

    overrides = parse_overrides(args.overrides)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True)))

    archs = [args.arch] if args.arch else arch_names()
    shapes = [args.shape] if args.shape else list(SHAPES)
    failures = []
    for mesh_name, mesh in meshes:
        outdir = os.path.join(args.out, mesh_name)
        os.makedirs(outdir, exist_ok=True)
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                ok, reason = applicable(cfg, SHAPES[shape_name])
                tag = f"--{args.tag}" if args.tag else ""
                path = os.path.join(outdir, f"{arch}--{shape_name}{tag}.json")
                if not ok:
                    with open(path, "w") as f:
                        json.dump({"arch": arch, "shape": shape_name,
                                   "mesh": mesh_name, "skipped": reason}, f,
                                  indent=1)
                    print(f"[skip] {mesh_name} {arch} {shape_name}: {reason}")
                    continue
                if os.path.exists(path) and not args.force:
                    print(f"[have] {mesh_name} {arch} {shape_name}")
                    continue
                print(f"[cell] {mesh_name} {arch} {shape_name} ...",
                      flush=True)
                try:
                    rec = run_cell(arch, shape_name, mesh, mesh_name,
                                   overrides)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    r = rec["roofline"]
                    print(f"       compile={rec['compile_s']:.1f}s "
                          f"mem/chip={rec['memory']['peak_per_chip_bytes']/2**30:.2f}GiB "
                          f"bottleneck={r['bottleneck']} "
                          f"roofline_frac={r['roofline_fraction']:.3f}",
                          flush=True)
                except Exception as e:      # a failing cell is a bug; record
                    failures.append((mesh_name, arch, shape_name, repr(e)))
                    with open(path + ".fail", "w") as f:
                        f.write(traceback.format_exc())
                    print(f"[FAIL] {mesh_name} {arch} {shape_name}: {e!r}",
                          flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f4 in failures:
            print("  ", *f4)
        return 1
    print("\nall requested cells green")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
