"""Per-(arch x shape) runtime knobs — the §Perf search space.

``cell_runtime`` returns the *tuned defaults* for one cell; the hillclimb
(benchmarks/roofline.py, EXPERIMENTS.md §Perf) overrides single knobs and
re-lowers.  The defaults encode the paper's methodology: a knowledge-base
of per-(SCT, workload) configurations — here literally a table keyed by
(architecture, shape) with derivation rules for unseen cells (size-class
nearest neighbour, the paper's Sec. 3.2.3 in miniature).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class CellConfig:
    """Runtime configuration of one (arch, shape) cell."""

    microbatches: int = 1
    remat: Optional[str] = "dots_no_batch"
    remat_group: int = 1
    remat_inner: Optional[str] = None
    loss_chunks: int = 1
    fsdp: bool = True            # shard weight 'embed' dim over data axes
    seq_shard: bool = False      # shard (cache_)seq over the model axis
    act_seq_shard: bool = False  # sequence parallelism: residual stream
                                 # seq dim over the model axis (archs whose
                                 # heads cannot shard, e.g. minicpm's 36)
    cache_dtype: str = "bf16"    # KV-cache storage ("bf16" | "f8")
    donate: bool = True

    def replace(self, **kw) -> "CellConfig":
        return dataclasses.replace(self, **kw)


def size_class(cfg: ModelConfig) -> str:
    p = cfg.param_count()
    if p > 3.0e10:
        return "big"             # mixtral-8x22b, command-r-plus-104b
    if p > 8.0e9:
        return "mid"             # internvl2-26b, nemotron-4-15b
    return "small"


#: tuned per-(arch, shape) configurations — the knowledge base of §Perf
#: hillclimb results (EXPERIMENTS.md), exactly the paper's per-(SCT,
#: workload) profile store.  act_seq_shard: sequence-parallel attention
#: for archs whose head count does not divide the 16-way model axis.
TUNED: Dict[Tuple[str, str], Dict] = {
    ("mixtral-8x22b", "train_4k"): {"microbatches": 8},
    ("nemotron-4-15b", "train_4k"): {"microbatches": 4},
    ("minicpm-2b", "prefill_32k"): {"act_seq_shard": True},
    ("gemma2-2b", "prefill_32k"): {"act_seq_shard": True},
    ("whisper-large-v3", "prefill_32k"): {"act_seq_shard": True},
    ("granite-moe-3b-a800m", "prefill_32k"): {"act_seq_shard": True},
    ("minicpm-2b", "train_4k"): {"act_seq_shard": True},
    ("gemma2-2b", "train_4k"): {"act_seq_shard": True},
    ("whisper-large-v3", "train_4k"): {"act_seq_shard": True},
}


def cell_runtime(cfg: ModelConfig, shape: ShapeSpec,
                 overrides: Optional[Dict] = None) -> CellConfig:
    sc = size_class(cfg)
    if shape.kind == "train":
        cell = CellConfig(
            microbatches={"big": 16, "mid": 8, "small": 4}[sc],
            remat="full",
            remat_group={"big": 8, "mid": 4, "small": 1}[sc],
            loss_chunks=8 if cfg.vocab >= 16_000 else 1,
            fsdp=True, seq_shard=False)
    elif shape.kind == "prefill":
        cell = CellConfig(
            microbatches=1, remat=None, loss_chunks=1,
            fsdp=(sc != "small"), seq_shard=True)
    else:  # decode
        cell = CellConfig(
            microbatches=1, remat=None, loss_chunks=1,
            fsdp=(sc != "small"), seq_shard=True,
            cache_dtype="bf16")
    tuned = TUNED.get((cfg.arch, shape.name))
    if tuned:
        cell = cell.replace(**tuned)
    if overrides:
        cell = cell.replace(**overrides)
    return cell
