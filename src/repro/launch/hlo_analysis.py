"""Loop-aware analysis of compiled HLO — roofline terms from the dry-run.

``compiled.cost_analysis()`` visits every computation **once**: a
scan-over-layers model reports one layer's FLOPs, not L layers' (verified
on this container — a 7-iteration scan of a matmul reports exactly one
matmul).  Since every assigned architecture is a ``lax.scan`` over stacked
layer parameters, all roofline terms here are computed by walking the HLO
text with **while-trip-count multipliers**:

  * FLOPs        — dot ops: ``2 · numel(result) · prod(contracting dims)``
                   (+1 flop/element for arithmetic elementwise ops);
  * HBM bytes    — per top-level (scheduled) op: operand + result bytes.
                   Fusion-internal ops don't touch memory and are skipped
                   (descended only for FLOPs);
  * collective bytes — operand bytes of all-gather / all-reduce /
                   reduce-scatter / all-to-all / collective-permute /
                   collective-broadcast (+ ragged/all-to-all variants).

Trip counts come from the ``known_trip_count`` backend_config that XLA
attaches to ``while`` ops, with a fallback to the loop-bound constant in
the condition computation.

The module is backend-agnostic text parsing — the same analyzer runs on
the CPU-compiled dry-run artifacts here and on real TPU HLO dumps.
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "token": 0, "opaque": 0,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([^\s=]+)\s*=\s*(\(?[^)]*?\)?[a-z0-9\[\],{}\s]*?)\s+"
    r"([a-z][a-z0-9\-]*)\(")
_CALLS_RE = re.compile(r"calls=%([^\s,)]+)")
_BODY_RE = re.compile(r"body=%([^\s,)]+)")
_COND_RE = re.compile(r"condition=%([^\s,)]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%([^\s,)]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[="\\{:\s]+n[="\\:\s]+"?(\d+)')
_OPERAND_RE = re.compile(r"%([A-Za-z0-9_.\-]+)")

#: elementwise arithmetic opcodes counted at 1 flop per output element
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "rsqrt", "sqrt",
    "tanh", "logistic", "maximum", "minimum", "atan2", "cbrt", "erf",
    "cosine", "sine",
}


def shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string (tuples summed)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def shape_numel(shape_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Op:
    name: str
    shape: str           # result shape string
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    by_name: Dict[str, Op]


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        # computation header: "%name (args) -> type {" or "ENTRY %name ..."
        m = re.match(r"^(?:ENTRY\s+)?%([^\s(]+)\s*\(.*\{\s*$", stripped)
        if m and not stripped.startswith("%param"):
            cur = Computation(name=m.group(1), ops=[], by_name={})
            comps[cur.name] = cur
            if stripped.startswith("ENTRY") or line.startswith("ENTRY"):
                comps["__entry__"] = cur
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        om = _OP_RE.match(line)
        if om:
            name, shape, opcode = om.group(1), om.group(2), om.group(3)
            op = Op(name=name, shape=shape.strip(), opcode=opcode, line=line)
            cur.ops.append(op)
            cur.by_name[name] = op
    return comps


def _trip_count(op: Op, comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(op.line)
    if m:
        return int(m.group(1))
    cm = _COND_RE.search(op.line)
    if cm and cm.group(1) in comps:
        cond = comps[cm.group(1)]
        consts = [int(v) for o in cond.ops
                  for v in re.findall(r"constant\((\d+)\)", o.line)]
        if consts:
            return max(consts)
    return 1


def _dot_flops(op: Op, comp: Computation) -> float:
    """2 * numel(result) * prod(lhs contracting dim sizes)."""
    lhs_dims_m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    operands = _operand_names(op)
    contract = 1
    if lhs_dims_m and operands:
        lhs = comp.by_name.get(operands[0])
        if lhs is not None:
            sm = _SHAPE_RE.search(lhs.shape)
            if sm:
                dims = [int(d) for d in sm.group(2).split(",") if d]
                for i in lhs_dims_m.group(1).split(","):
                    if i and int(i) < len(dims):
                        contract *= dims[int(i)]
    return 2.0 * shape_numel(op.shape) * contract


def _operand_names(op: Op) -> List[str]:
    # names inside the op's (...) argument list, before any attribute
    inner = op.line.split(op.opcode + "(", 1)
    if len(inner) < 2:
        return []
    args = inner[1]
    depth = 1
    out_chars = []
    for ch in args:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        out_chars.append(ch)
    return _OPERAND_RE.findall("".join(out_chars))


_MEM_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


#: ops whose operands stream from memory as real kernels; everything else
#: elementwise/shape-only would be fused into a producer on the TPU
#: backend, so only its *result* is charged ("each tensor materialised
#: at most once" traffic model)
_HEAVY_OPS = {
    "fusion", "copy", "dynamic-update-slice", "dynamic-slice", "gather",
    "scatter", "sort", "reduce", "reduce-window", "concatenate", "pad",
    "custom-call", "select-and-scatter", "cholesky", "triangular-solve",
    "fft", "rng", "rng-bit-generator",
}


@dataclasses.dataclass
class Analysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_count: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    #: f32 copies of bf16 buffers inserted by the CPU backend's
    #: float-normalisation (no native bf16) — absent on the TPU target
    legalization_bytes: float = 0.0
    warnings: List[str] = dataclasses.field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def to_json(self) -> Dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "collective_bytes": dict(self.collective_bytes),
                "collective_count": dict(self.collective_count),
                "total_collective_bytes": self.total_collective_bytes,
                "legalization_bytes": self.legalization_bytes,
                "warnings": list(self.warnings)}


def analyze(hlo: str) -> Analysis:
    comps = parse_computations(hlo)
    out = Analysis()
    entry = comps.get("__entry__")
    if entry is None:
        out.warnings.append("no ENTRY computation found")
        return out
    _walk(entry, 1.0, comps, out, for_bytes=True, seen=set())
    return out


def _walk(comp: Computation, mult: float, comps: Dict[str, Computation],
          out: Analysis, *, for_bytes: bool, seen: set) -> None:
    if (comp.name, for_bytes) in seen:
        # a computation may be called from several sites; each call site
        # contributes its own multiplier, so recursion is by call site —
        # `seen` only guards direct self-recursion (not valid HLO anyway)
        pass
    for op in comp.ops:
        oc = op.opcode
        if oc == "while":
            n = _trip_count(op, comps)
            bm = _BODY_RE.search(op.line)
            cm = _COND_RE.search(op.line)
            for ref, m2 in ((bm, n), (cm, n + 1)):
                if ref and ref.group(1) in comps:
                    _walk(comps[ref.group(1)], mult * m2, comps, out,
                          for_bytes=for_bytes, seen=seen)
            continue
        if oc == "conditional":
            br = _BRANCHES_RE.search(op.line)
            if br:
                for name in _OPERAND_RE.findall(br.group(1)):
                    if name in comps:
                        # branches are exclusive; worst-case bound: walk all
                        _walk(comps[name], mult, comps, out,
                              for_bytes=for_bytes, seen=seen)
            continue
        if oc in ("call", "async-start", "custom-call"):
            tm = _TO_APPLY_RE.search(op.line) or _CALLS_RE.search(op.line)
            if tm and tm.group(1) in comps:
                _walk(comps[tm.group(1)], mult, comps, out,
                      for_bytes=for_bytes, seen=seen)
            # fallthrough: custom-call result bytes still counted below
        if oc == "fusion":
            cm = _CALLS_RE.search(op.line)
            if cm and cm.group(1) in comps:
                # descend for FLOPs only: internal ops don't touch HBM
                _walk(comps[cm.group(1)], mult, comps, out,
                      for_bytes=False, seen=seen)
            if for_bytes:
                out.hbm_bytes += mult * _op_bytes(op, comp)
            continue

        # ---- leaf ops -----------------------------------------------------
        if oc.startswith("dot"):
            out.flops += mult * _dot_flops(op, comp)
            if for_bytes:
                out.hbm_bytes += mult * _op_bytes(op, comp)
            continue
        if oc == "convert" and mult <= 1.0:
            # whole-buffer f32 copies of bf16 data = CPU float-normalisation
            b = shape_bytes(op.shape)
            if "f32" in op.shape and b > (256 << 20):
                srcs = _operand_names(op)
                src = comp.by_name.get(srcs[0]) if srcs else None
                if src is not None and "bf16" in src.shape:
                    out.legalization_bytes += b
        if oc in _ELEMENTWISE:
            out.flops += mult * shape_numel(op.shape)
            if for_bytes:
                out.hbm_bytes += mult * shape_bytes(op.shape)
            continue
        is_coll = next((c for c in COLLECTIVES
                        if oc == c or oc == c + "-start"
                        or oc == c.replace("-", "_")), None)
        if is_coll:
            b = mult * _operand_bytes(op, comp)
            out.collective_bytes[is_coll] = \
                out.collective_bytes.get(is_coll, 0.0) + b
            out.collective_count[is_coll] = \
                out.collective_count.get(is_coll, 0) + int(round(mult))
            if for_bytes:
                out.hbm_bytes += mult * _op_bytes(op, comp)
            continue
        if oc in _MEM_FREE_OPS or oc.endswith("-done"):
            continue
        if for_bytes:
            if oc in _HEAVY_OPS:
                out.hbm_bytes += mult * _op_bytes(op, comp)
            else:
                # elementwise/layout op: charge the result only (it would
                # fuse into its producer/consumer on the TPU backend)
                out.hbm_bytes += mult * shape_bytes(op.shape)


def _op_bytes(op: Op, comp: Computation) -> float:
    return shape_bytes(op.shape) + _operand_bytes(op, comp)


def _operand_bytes(op: Op, comp: Computation) -> float:
    total = 0.0
    for name in _operand_names(op):
        src = comp.by_name.get(name)
        if src is not None:
            total += shape_bytes(src.shape)
    return total
