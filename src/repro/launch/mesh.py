"""Production meshes (pure functions — importing never touches jax device
state; the dry-run sets XLA_FLAGS *before* any jax initialisation).

Topology (TPU v5e numbers; DESIGN.md §2):
  single-pod: (data=16, model=16)            = 256 chips
  multi-pod:  (pod=2, data=16, model=16)     = 512 chips

``pod`` is the slowest axis (DCN between pods), ``model`` the fastest
(ICI ring within hosts) — the axis order mirrors the physical hierarchy
so GSPMD's collective scheduling maps pod-crossing traffic onto the
data-parallel gradient reduction only.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

SINGLE_POD = (16, 16)
SINGLE_POD_AXES = ("data", "model")
MULTI_POD = (2, 16, 16)
MULTI_POD_AXES = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False,
                         devices: Optional[Sequence] = None) -> Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = MULTI_POD_AXES if multi_pod else SINGLE_POD_AXES
    n = 1
    for s in shape:
        n *= s
    if devices is None:
        devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_"
            "count=512 before importing jax")
    return jax.make_mesh(shape, axes, devices=list(devices)[:n])


def make_host_mesh(axes: Tuple[str, ...] = ("data",)) -> Mesh:
    """Whatever this host actually has (smoke tests, examples)."""
    n = len(jax.devices())
    shape = (n,) + (1,) * (len(axes) - 1)
    return jax.make_mesh(shape, axes)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def mesh_chips(mesh: Mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
