"""SCT semantics: Pipeline/Loop/Map/MapReduce + scheduler end-to-end
(paper Sec. 2, Fig. 4)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AcceleratorPlatform, DeviceInfo, HostPlatform,
                        KernelSpec, KnowledgeBase, Loop, LoopState, Map,
                        MapReduce, MERGE_ADD, Pipeline, Scheduler, Session,
                        ThreadedExecutor, Trait, kernel, scalar, vector)


def saxpy_tree():
    return kernel(lambda a, x, y: a * x + y, name="saxpy",
                  inputs=[scalar("a"), vector("x"), vector("y")],
                  outputs=[vector("z")])


class TestSkeletons:
    def test_pipeline_depth_first(self):
        k1 = kernel(lambda x: x + 1, name="k1", inputs=[vector("x")],
                    outputs=[vector("m")])
        k2 = kernel(lambda m: m * 3, name="k2", inputs=[vector("m")],
                    outputs=[vector("y")])
        env = Pipeline(k1, k2).apply({"x": jnp.array([1.0, 2.0])})
        np.testing.assert_allclose(env["y"], [6.0, 9.0])

    def test_loop_for(self):
        body = kernel(lambda x: x * 2, name="dbl", inputs=[vector("x")],
                      outputs=[vector("x")])
        loop = Loop(body, LoopState(max_iterations=4))
        env = loop.apply({"x": jnp.array([1.0])})
        assert float(env["x"][0]) == 16.0

    def test_loop_while_with_state(self):
        body = kernel(lambda x: x + 1, name="inc", inputs=[vector("x")],
                      outputs=[vector("x")])
        loop = Loop(body, LoopState(cond=lambda e: e["x"][0] < 10))
        env = loop.apply({"x": jnp.array([0.0])})
        assert float(env["x"][0]) == 10.0

    def test_mapreduce_host_side(self):
        sq = kernel(lambda x: x * x, name="sq", inputs=[vector("x")],
                    outputs=[vector("s")])
        mr = MapReduce(sq, lambda s: jnp.sum(s), out_name="total")
        env = mr.apply({"x": jnp.array([1.0, 2.0, 3.0])})
        assert float(env["total"]) == 14.0

    def test_size_offset_traits(self):
        k = kernel(lambda x, n, off: x * 0 + n + off, name="k",
                   inputs=[vector("x"), scalar("n", trait=Trait.SIZE),
                           scalar("off", trait=Trait.OFFSET)],
                   outputs=[vector("y")])
        env = k.apply({"x": jnp.zeros(8)})
        assert float(env["y"][0]) == 8.0      # size=8, offset=0

    def test_unique_id_structural(self):
        a = Pipeline(saxpy_tree())
        b = Pipeline(saxpy_tree())
        assert a.unique_id() == b.unique_id()
        assert Map(saxpy_tree()).unique_id() != a.unique_id()


def make_scheduler(**kw):
    host = HostPlatform(DeviceInfo("cpu0", "cpu", compute_units=8),
                        topology={"L1": 8, "L2": 4, "L3": 2,
                                  "NO_FISSION": 1})
    accel = AcceleratorPlatform([DeviceInfo("gpu0", "gpu")], max_overlap=4)
    return Scheduler(host=host, accel=accel, executor=ThreadedExecutor(),
                     kb=KnowledgeBase(), **kw)


class TestSchedulerEndToEnd:
    def test_correct_result_any_distribution(self):
        sched = make_scheduler(default_share_a=0.6)
        sct = saxpy_tree()
        x = np.arange(64, dtype=np.float32)
        y = np.ones(64, dtype=np.float32)
        run = sched.run(sct, {"a": np.float32(2.0), "x": x, "y": y})
        np.testing.assert_allclose(run.outputs["z"], 2 * x + y)
        assert run.action in ("derived", "exact")

    def test_recurrent_execution_reuses_profile(self):
        sched = make_scheduler()
        sct = saxpy_tree()
        arrays = {"a": np.float32(1.0),
                  "x": np.ones(32, np.float32),
                  "y": np.zeros(32, np.float32)}
        first = sched.run(sct, arrays)
        second = sched.run(sct, arrays)
        assert second.action in ("reused", "adjusted")

    def test_workload_change_triggers_derivation(self):
        sched = make_scheduler()
        sct = saxpy_tree()
        sched.run(sct, {"a": np.float32(1.0), "x": np.ones(32, np.float32),
                        "y": np.zeros(32, np.float32)})
        run = sched.run(sct, {"a": np.float32(1.0),
                              "x": np.ones(64, np.float32),
                              "y": np.zeros(64, np.float32)})
        assert run.action in ("derived", "exact")
        assert len(sched.kb) >= 2

    def test_session_future(self):
        sched = make_scheduler()
        sess = Session(sched)
        fut = sess.run(saxpy_tree(), a=np.float32(3.0),
                       x=np.ones(16, np.float32),
                       y=np.zeros(16, np.float32))
        out = fut.get(timeout=60)
        np.testing.assert_allclose(out.outputs["z"], 3.0)
        sess.shutdown()

    def test_merge_functions(self):
        sq = kernel(lambda x: jnp.sum(x * x)[None], name="sq",
                    inputs=[vector("x")], outputs=[vector("partial")])
        sched = make_scheduler()
        sched.executor.merges["partial"] = MERGE_ADD
        x = np.arange(16, dtype=np.float32)
        run = sched.run(Map(sq), {"x": x})
        np.testing.assert_allclose(np.asarray(run.outputs["partial"]).sum(),
                                   float((x * x).sum()), rtol=1e-5)
