"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the host's real (single) device; only the dry-run uses 512
placeholder devices (and only as a program entry point)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
