"""Dynamic load balancing: lbt threshold + corrector (paper Sec. 3.3)."""
import pytest

from repro.core import Distribution, ExecutionStats, LoadBalancer


def stats(times, share=0.8):
    return ExecutionStats(times=list(times), share_a=share)


class TestDetector:
    def test_balanced_run_keeps_lbt_low(self):
        lb = LoadBalancer(max_dev=0.85)
        for _ in range(10):
            assert not lb.observe(stats([1.0, 0.95, 0.9]))
        assert lb.lbt < 0.1

    def test_unbalanced_takes_3_to_4_runs(self):
        """Paper: weight=2/3 -> 3-4 consecutive unbalanced runs trigger."""
        lb = LoadBalancer(max_dev=0.85, weight=2 / 3, trigger=0.9)
        fired_at = None
        for n in range(1, 10):
            if lb.observe(stats([1.0, 0.4])):
                fired_at = n
                break
        assert fired_at in (3, 4)

    def test_sporadic_unbalance_filtered(self):
        lb = LoadBalancer(max_dev=0.85)
        seq = [[1.0, 0.95], [1.0, 0.4], [1.0, 0.95], [1.0, 0.97],
               [1.0, 0.4], [1.0, 0.96]]
        assert not any(lb.observe(stats(t)) for t in seq)

    def test_c_factor_tolerates_by_design_unbalance(self):
        lb_strict = LoadBalancer(max_dev=0.85, c_factor=1.0)
        lb_loose = LoadBalancer(max_dev=0.85, c_factor=0.8)
        dev = 0.7
        assert lb_strict.is_unbalanced(dev)
        assert not lb_loose.is_unbalanced(dev)

    def test_deviation_definition(self):
        assert stats([2.0, 1.0]).deviation == pytest.approx(0.5)
        assert stats([1.0, 1.0]).deviation == pytest.approx(1.0)


class TestCorrector:
    def test_adjust_moves_towards_faster_class(self):
        lb = LoadBalancer()
        cur = Distribution(a=0.5, b=0.5)
        new = lb.adjust(cur, stats_a=1.0, stats_b=3.0)
        assert new.a > 0.5
        assert lb.balance_ops == 1

    def test_consecutive_adjusts_accelerate(self):
        """Shifting phase of Fig. 11: repeated one-direction corrections
        grow the step (adaptive search doubling)."""
        lb = LoadBalancer()
        cur = Distribution(a=0.3, b=0.7)
        deltas = []
        for _ in range(5):
            new = lb.adjust(cur, 1.0, 4.0)
            deltas.append(new.a - cur.a)
            cur = new
        assert deltas[-1] > deltas[0]
