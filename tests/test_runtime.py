"""Runtime substrate: loss chunking, microbatching, optimizer, schedules,
gradient compression, data pipeline, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, batch_at, host_shard_batch
from repro.models import ModelConfig, init_tree, model_defs
from repro.optim import (AdamW, AdamWConfig, CompressionState,
                         compress_gradients, cosine_schedule,
                         decompress_sum, dequantize_int8, init_compression,
                         quantize_int8, shared_scale, wsd_schedule)
from repro.runtime import (RuntimeConfig, chunked_xent, init_state,
                           make_train_step, xent_from_logits)

CFG = ModelConfig(arch="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=300)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

class TestLoss:
    def test_chunked_equals_unchunked(self):
        params = init_tree(jax.random.PRNGKey(0), model_defs(CFG))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64),
                              jnp.float32)
        labels = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 300)
        t1, n1 = chunked_xent(x, params, CFG, labels, chunks=1)
        t4, n4 = chunked_xent(x, params, CFG, labels, chunks=4)
        assert_allclose(t1, t4, rtol=1e-5)
        assert n1 == n4

    def test_ignore_labels(self):
        logits = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 10))
        labels = jnp.array([[1, -1, 2, -1]])
        s, n = xent_from_logits(logits, labels)
        assert n == 2.0

    def test_padded_vocab_invisible(self):
        """Loss over a padded-vocab model equals the same computation with
        the mask: padded ids contribute exp(-inf) = 0 to the lse."""
        cfg = ModelConfig(arch="p", family="dense", n_layers=1, d_model=32,
                          n_heads=2, n_kv_heads=2, d_ff=64, vocab=300,
                          vocab_pad_multiple=128)
        assert cfg.padded_vocab == 384
        params = init_tree(jax.random.PRNGKey(0), model_defs(cfg),
                           jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
        labels = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0, 300)
        tot, _ = chunked_xent(x, params, cfg, labels, chunks=1)
        # manual: true-vocab slice only
        w = params["embed"]["unembed"][:, :300]
        logits = x @ w
        want, _ = xent_from_logits(logits, labels)
        assert_allclose(tot, want, rtol=1e-4)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

class TestTrainStep:
    def make(self, rt):
        params = init_tree(jax.random.PRNGKey(0), model_defs(CFG))
        opt = AdamW(AdamWConfig(lr=1e-3))
        return init_state(params, opt), jax.jit(
            make_train_step(CFG, opt, rt))

    def batch(self, B=8, S=16):
        tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, 300)
        return {"tokens": tokens,
                "labels": jnp.roll(tokens, -1, axis=1)}

    def test_microbatching_matches_full_batch(self):
        """Gradient accumulation is algebraically the mean of shards."""
        s1, f1 = self.make(RuntimeConfig(microbatches=1, remat=None))
        s4, f4 = self.make(RuntimeConfig(microbatches=4, remat=None))
        b = self.batch()
        _, m1 = f1(s1, b)
        _, m4 = f4(s4, b)
        assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-4)
        assert_allclose(float(m1["grad_norm"]), float(m4["grad_norm"]),
                        rtol=2e-2)

    def test_remat_matches_no_remat(self):
        s1, f1 = self.make(RuntimeConfig(remat=None))
        s2, f2 = self.make(RuntimeConfig(remat="full", remat_group=2))
        b = self.batch()
        _, m1 = f1(s1, b)
        _, m2 = f2(s2, b)
        assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
        assert_allclose(float(m1["grad_norm"]), float(m2["grad_norm"]),
                        rtol=2e-2)


# ---------------------------------------------------------------------------
# optimizer + schedules
# ---------------------------------------------------------------------------

class TestOptim:
    def test_weight_decay_mask(self):
        opt = AdamW(AdamWConfig(weight_decay=0.5, lr=0.1, grad_clip=0))
        params = {"w": jnp.ones((4, 4)), "norm_scale": jnp.ones((4,))}
        mask = opt._decay_mask(params)
        assert mask["w"] == 1.0 and mask["norm_scale"] == 0.0

    def test_step_reduces_quadratic(self):
        opt = AdamW(AdamWConfig(lr=0.1, weight_decay=0.0))
        params = {"w": jnp.array([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(120):
            grads = {"w": params["w"]}              # d/dw (w^2/2)
            params, state, _ = opt.update(grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_wsd_shape(self):
        f = wsd_schedule(1.0, warmup=10, stable=50, decay=20)
        assert float(f(0)) < 0.2
        assert float(f(30)) == pytest.approx(1.0)
        assert float(f(59)) == pytest.approx(1.0)
        assert float(f(80)) < 0.05

    def test_cosine_shape(self):
        f = cosine_schedule(1.0, warmup=10, total=100, final_ratio=0.1)
        assert float(f(10)) == pytest.approx(1.0, abs=0.05)
        assert float(f(99)) == pytest.approx(0.1, abs=0.03)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

class TestCompression:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 1000))
    def test_quantize_roundtrip_error_bounded(self, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 10
        q, s = quantize_int8(x)
        err = jnp.abs(dequantize_int8(q, s) - x)
        assert float(err.max()) <= float(s) * 0.5 + 1e-6

    def test_error_feedback_accumulates(self):
        """Repeated compression of a constant gradient converges to it."""
        g = {"w": jnp.full((32,), 0.337)}
        st_ = init_compression(g)
        total = jnp.zeros((32,))
        for _ in range(20):
            scales = shared_scale(g, st_, axis=None)
            q, st_ = compress_gradients(g, st_, scales)
            total += decompress_sum(
                jax.tree.map(lambda x: x.astype(jnp.int32), q),
                scales, 1)["w"]
        assert_allclose(total / 20, g["w"], rtol=1e-2)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

class TestData:
    def test_deterministic(self):
        dc = DataConfig(vocab=100, seq_len=16, global_batch=4)
        b1, b2 = batch_at(dc, 7), batch_at(dc, 7)
        assert jnp.array_equal(b1["tokens"], b2["tokens"])

    def test_steps_differ(self):
        dc = DataConfig(vocab=100, seq_len=16, global_batch=4)
        assert not jnp.array_equal(batch_at(dc, 1)["tokens"],
                                   batch_at(dc, 2)["tokens"])

    def test_labels_are_shifted_tokens(self):
        dc = DataConfig(vocab=100, seq_len=16, global_batch=2)
        b = batch_at(dc, 0)
        assert jnp.array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
        assert (b["labels"][:, -1] == -1).all()

    def test_host_shards_tile_global(self):
        dc = DataConfig(vocab=100, seq_len=8, global_batch=8)
        full = batch_at(dc, 3)["tokens"]
        parts = [host_shard_batch(dc, 3, host_index=i, host_count=4)
                 ["tokens"] for i in range(4)]
        assert jnp.array_equal(jnp.concatenate(parts, 0), full)

    def test_tokens_in_vocab(self):
        dc = DataConfig(vocab=37, seq_len=64, global_batch=2)
        t = batch_at(dc, 0)["tokens"]
        assert int(t.min()) >= 0 and int(t.max()) < 37


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def tree(self):
        return {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.float32)}}

    def test_roundtrip_bf16(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        mgr.save(1, self.tree(), blocking=True)
        got, meta = mgr.restore_latest(self.tree())
        assert meta.step == 1
        assert got["a"].dtype == np.asarray(self.tree()["a"]).dtype
        assert_allclose(np.asarray(got["a"], np.float32),
                        np.asarray(self.tree()["a"], np.float32))

    def test_keep_k_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, self.tree(), blocking=True)
        assert mgr.steps() == [3, 4]

    def test_corrupt_newest_falls_back(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save(1, self.tree(), blocking=True)
        mgr.save(2, self.tree(), blocking=True)
        os.remove(os.path.join(str(tmp_path), "step_000000000002",
                               "proc00000", "arrays.npz"))
        got, meta = mgr.restore_latest(self.tree())
        assert meta.step == 1

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        mgr.save(1, self.tree(), blocking=True)
        bad = {"a": jnp.zeros((3, 3), jnp.bfloat16),
               "b": {"c": jnp.ones((4,), jnp.float32)}}
        assert mgr.restore_latest(bad) is None


# ---------------------------------------------------------------------------
# int8 + error-feedback DP train step (explicit-collective path)
# ---------------------------------------------------------------------------

class TestInt8DPStep:
    def test_trains_close_to_plain_step(self):
        """On a 1-shard mesh the int8 sync is pure quantisation; with
        error feedback the parameter trajectory must track the exact
        step closely."""
        import jax
        from repro.launch.mesh import make_host_mesh
        from repro.runtime import make_dp_train_step_int8

        mesh = make_host_mesh(("data",))
        opt = AdamW(AdamWConfig(lr=1e-3))
        params = init_tree(jax.random.PRNGKey(0), model_defs(CFG),
                           jnp.float32)
        rt = RuntimeConfig(remat=None)
        plain = jax.jit(make_train_step(CFG, opt, rt))
        comp = jax.jit(make_dp_train_step_int8(CFG, opt, rt, mesh))

        s_plain = init_state(params, opt)
        s_comp = init_state(params, opt, compress=True)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 300)
        batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
        first = None
        for _ in range(5):
            s_plain, m_plain = plain(s_plain, batch)
            s_comp, m_comp = comp(s_comp, batch)
            first = first if first is not None else float(m_comp["loss"])
        # quantisation noise feeds Adam's nonlinearity, so trajectories
        # drift slowly — the property is comparable convergence (<2%),
        # not bitwise equality
        assert_allclose(float(m_plain["loss"]), float(m_comp["loss"]),
                        rtol=2e-2)
        assert float(m_comp["loss"]) < first          # actually training
