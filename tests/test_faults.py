"""Fault-tolerant execution: slot containment, repartition-retry, the
watchdog, device quarantine/reinstatement, and fault-noise isolation
(repro.core.faults + hooks in executor/simulator/scheduler)."""
import math

import numpy as np
import pytest

from repro.core import (AcceleratorPlatform, DeviceInfo, DeviceHealth,
                        ExecutionError, ExecutionSlot, ExecutionStats,
                        FaultInjector, FaultPolicy, FaultRecord, HostPlatform,
                        KnowledgeBase, LoadBalancer, PlatformConfig, Profile,
                        Scheduler, Session, ThreadedExecutor, build_plan,
                        kernel, scalar, vector)
from repro.core.load_balancer import class_times
from repro.core.simulator import SimDevice, SimulatedExecutor
from repro.core.spec import Workload


def saxpy_tree():
    return kernel(lambda a, x, y: a * x + y, name="saxpy",
                  inputs=[scalar("a"), vector("x"), vector("y")],
                  outputs=[vector("z")])


def saxpy_arrays(n=64, a=2.0):
    return {"a": np.float32(a),
            "x": np.arange(n, dtype=np.float32),
            "y": np.ones(n, dtype=np.float32)}


def make_profile(sct, n=64, share=0.5):
    return Profile(sct_id=sct.unique_id(), workload=Workload((n,)),
                   share_a=share, config=PlatformConfig(),
                   best_time=math.inf)


def three_slot_part(sct, n=64):
    plan = build_plan(sct, {"x": (n,), "y": (n,)})
    slots = [ExecutionSlot("gpu0/q0", "gpu"),
             ExecutionSlot("cpu0/f0", "cpu"),
             ExecutionSlot("cpu0/f1", "cpu")]
    return plan.partition(slots, [0.5, 0.25, 0.25])


def make_scheduler(executor, **kw):
    host = HostPlatform(DeviceInfo("cpu0", "cpu", compute_units=4),
                        topology={"L2": 2, "NO_FISSION": 1})
    accel = AcceleratorPlatform([DeviceInfo("gpu0", "gpu")], max_overlap=2)
    return Scheduler(host=host, accel=accel, executor=executor,
                     kb=KnowledgeBase(), **kw)


def sim_devices():
    return [SimDevice("gpu0", "gpu", flops=1e12),
            SimDevice("cpu0", "cpu", flops=1e11, cores=4)]


# ---------------------------------------------------------------------------
# FaultInjector determinism
# ---------------------------------------------------------------------------

class TestInjector:
    def test_seeded_sequence_is_deterministic(self):
        def drive(inj):
            return [inj.decide(d) for d in
                    ["gpu0/q0", "cpu0/f0", "gpu0/q0", "cpu0/f1"] * 10]
        a = FaultInjector(seed=42, crash_prob=0.3, stall_prob=0.2)
        b = FaultInjector(seed=42, crash_prob=0.3, stall_prob=0.2)
        assert drive(a) == drive(b)
        assert a.injected == b.injected
        assert any(k == "crash" for k, _, _ in a.injected)

    def test_nth_call_trigger_counts_per_device(self):
        inj = FaultInjector(crash_on_call={"gpu0": [2]})
        assert inj.decide("gpu0/q0") is None       # call 1
        assert inj.decide("cpu0/f0") is None       # other device
        assert inj.decide("gpu0/q1") == "crash"    # call 2 (same base dev)
        assert inj.decide("gpu0/q0") is None       # call 3

    def test_per_device_probability_override(self):
        inj = FaultInjector(seed=0, device_crash_prob={"gpu0": 1.0})
        assert inj.decide("gpu0/q0") == "crash"
        assert inj.decide("cpu0/f0") is None


# ---------------------------------------------------------------------------
# ThreadedExecutor: containment, repartition-retry, watchdog
# ---------------------------------------------------------------------------

class TestThreadedExecutorFaults:
    def test_crash_repartitions_and_matches_reference(self):
        sct = saxpy_tree()
        arrays = saxpy_arrays()
        ref = ThreadedExecutor().execute(
            sct, three_slot_part(sct), arrays, make_profile(sct))[0]

        inj = FaultInjector(crash_on_call={"gpu0": [1]})
        ex = ThreadedExecutor(injector=inj)
        out, times = ex.execute(sct, three_slot_part(sct), arrays,
                                make_profile(sct))
        np.testing.assert_array_equal(out["z"], ref["z"])
        assert ex.last_retries == 1
        assert len(ex.last_failures) == 1
        rec = ex.last_failures[0]
        assert rec.device_base == "gpu0" and rec.kind == "crash"
        assert len(times) == 3                     # one entry per slot

    def test_user_kernel_exception_is_contained(self):
        boom = kernel(lambda x: (_ for _ in ()).throw(ValueError("boom")),
                      name="boom", inputs=[vector("x")],
                      outputs=[vector("y")])
        plan = build_plan(boom, {"x": (8,)})
        part = plan.partition([ExecutionSlot("cpu0/f0", "cpu"),
                               ExecutionSlot("cpu0/f1", "cpu")], [0.5, 0.5])
        ex = ThreadedExecutor()
        with pytest.raises(ExecutionError) as ei:
            ex.execute(boom, part, {"x": np.ones(8, np.float32)},
                       make_profile(boom, 8))
        assert "ValueError: boom" in str(ei.value)
        assert all(r.kind == "crash" for r in ei.value.records)

    def test_exhausted_retries_raises_with_records(self):
        sct = saxpy_tree()
        inj = FaultInjector(crash_on_call={"gpu0": [1], "cpu0": [3]})
        ex = ThreadedExecutor(injector=inj, policy=FaultPolicy(max_attempts=2))
        with pytest.raises(ExecutionError, match="retries exhausted") as ei:
            ex.execute(sct, three_slot_part(sct), saxpy_arrays(),
                       make_profile(sct))
        kinds = [(r.device_base, r.kind) for r in ei.value.records]
        assert ("gpu0", "crash") in kinds and ("cpu0", "crash") in kinds
        assert ei.value.attempts == 2

    def test_all_slots_dead_is_partition_lost(self):
        sct = saxpy_tree()
        inj = FaultInjector(crash_prob=1.0)
        ex = ThreadedExecutor(injector=inj)
        with pytest.raises(ExecutionError, match="partition lost"):
            ex.execute(sct, three_slot_part(sct), saxpy_arrays(),
                       make_profile(sct))

    def test_watchdog_fires_on_stalled_slot(self):
        sct = saxpy_tree()
        inj = FaultInjector(stall_on_call={"gpu0": [1]}, stall_seconds=5.0)
        ex = ThreadedExecutor(
            injector=inj,
            policy=FaultPolicy(max_attempts=2, default_deadline=0.3))
        out, _ = ex.execute(sct, three_slot_part(sct), saxpy_arrays(),
                            make_profile(sct))
        assert ex.last_failures and ex.last_failures[0].kind == "timeout"
        assert ex.last_retries == 1
        x = saxpy_arrays()["x"]
        np.testing.assert_array_equal(out["z"], 2.0 * x + 1.0)

    def test_deadline_derived_from_best_time(self):
        p = FaultPolicy(watchdog_multiple=8.0, min_deadline=0.25)
        assert p.deadline(1.0) == 8.0
        assert p.deadline(0.001) == 0.25           # floored
        assert p.deadline(math.inf) is None        # unknown -> default (None)
        assert FaultPolicy(default_deadline=2.0).deadline(math.inf) == 2.0


# ---------------------------------------------------------------------------
# SimulatedExecutor honours the same injector/policy
# ---------------------------------------------------------------------------

class TestSimulatedExecutorFaults:
    def test_sim_crash_retries_deterministically(self):
        sct = saxpy_tree()

        def run():
            inj = FaultInjector(crash_on_call={"gpu0": [1]})
            sim = SimulatedExecutor(sim_devices(), seed=3, injector=inj)
            _, times = sim.execute(sct, three_slot_part(sct), saxpy_arrays(),
                                   make_profile(sct))
            return times, sim.last_retries, [r.kind for r in sim.last_failures]

        t1, r1, k1 = run()
        t2, r2, k2 = run()
        assert t1 == t2 and r1 == r2 == 1 and k1 == k2 == ["crash"]

    def test_sim_stall_trips_watchdog(self):
        sct = saxpy_tree()
        inj = FaultInjector(stall_on_call={"gpu0": [1]}, stall_seconds=10.0)
        sim = SimulatedExecutor(
            sim_devices(), injector=inj,
            policy=FaultPolicy(default_deadline=1.0))
        _, times = sim.execute(sct, three_slot_part(sct), saxpy_arrays(),
                               make_profile(sct))
        assert sim.last_failures[0].kind == "timeout"
        assert times[0] == pytest.approx(1.0)      # charged the deadline

    def test_sim_total_loss_raises(self):
        sct = saxpy_tree()
        inj = FaultInjector(crash_prob=1.0)
        sim = SimulatedExecutor(sim_devices(), injector=inj)
        with pytest.raises(ExecutionError):
            sim.execute(sct, three_slot_part(sct), saxpy_arrays(),
                        make_profile(sct))


# ---------------------------------------------------------------------------
# Scheduler: end-to-end recovery, quarantine, reinstatement, noise isolation
# ---------------------------------------------------------------------------

class TestSchedulerFaultTolerance:
    def test_scheduled_run_survives_accelerator_loss(self):
        """Acceptance: seeded injector kills one accelerator slot; the run
        completes with outputs matching the fault-free reference and
        reports retries >= 1."""
        sct = saxpy_tree()
        arrays = saxpy_arrays()
        ref = make_scheduler(ThreadedExecutor()).run(sct, dict(arrays))

        inj = FaultInjector(seed=7, crash_on_call={"gpu0": [1]})
        sched = make_scheduler(ThreadedExecutor(injector=inj))
        run = sched.run(sct, dict(arrays))
        np.testing.assert_array_equal(run.outputs["z"], ref.outputs["z"])
        assert run.stats.retries >= 1
        assert not run.stats.ok
        assert run.stats.failures[0].device_base == "gpu0"

    def test_quarantine_then_probation_then_reinstatement(self):
        sct = saxpy_tree()
        arrays = saxpy_arrays()
        inj = FaultInjector(crash_on_call={"gpu0": [1, 2]})
        sched = make_scheduler(
            SimulatedExecutor(sim_devices(), injector=inj),
            health=DeviceHealth(quarantine_after=2, probe_after=2))

        r1 = sched.run(sct, dict(arrays))          # gpu0 fault #1
        assert not r1.stats.ok
        assert not sched.health.is_quarantined("gpu0")

        r2 = sched.run(sct, dict(arrays))          # gpu0 fault #2 -> out
        assert not r2.stats.ok
        assert sched.health.is_quarantined("gpu0")

        r3 = sched.run(sct, dict(arrays))          # degraded: CPU-only
        assert r3.stats.ok
        assert all(not s.device.startswith("gpu0")
                   for s in sched._last_slots)

        r4 = sched.run(sct, dict(arrays))          # probe run: gpu0 back
        assert any(s.device.startswith("gpu0") for s in sched._last_slots)
        assert r4.stats.ok
        assert not sched.health.is_quarantined("gpu0")   # reinstated

        r5 = sched.run(sct, dict(arrays))          # fully back
        assert any(s.device.startswith("gpu0") for s in sched._last_slots)

    def test_all_devices_quarantined_is_terminal(self):
        sct = saxpy_tree()
        inj = FaultInjector(crash_prob=1.0)
        sched = make_scheduler(
            SimulatedExecutor(sim_devices(), injector=inj,
                              policy=FaultPolicy(max_attempts=1)),
            health=DeviceHealth(quarantine_after=1, probe_after=100))
        with pytest.raises(ExecutionError):
            sched.run(sct, saxpy_arrays())         # run fails, all devs out
        with pytest.raises(ExecutionError, match="quarantined"):
            sched.run(sct, saxpy_arrays())         # no slots left at all

    def test_failed_runs_do_not_feed_balancer_or_kb(self):
        sct = saxpy_tree()
        arrays = saxpy_arrays()
        inj = FaultInjector(crash_on_call={"gpu0": [1, 2, 3]})
        sched = make_scheduler(
            SimulatedExecutor(sim_devices(), injector=inj),
            health=DeviceHealth(quarantine_after=99))
        for _ in range(3):
            run = sched.run(sct, dict(arrays))
            assert not run.stats.ok
        assert sched.balancer.lbt == 0.0
        assert sched.balancer.unbalanced_runs == 0
        stored = sched.kb.exact(sct.unique_id(), Workload((64,)))
        assert stored is not None and stored.best_time == math.inf

    def test_per_class_makespans_recorded_on_stats(self):
        sct = saxpy_tree()
        sched = make_scheduler(SimulatedExecutor(sim_devices()))
        run = sched.run(sct, saxpy_arrays())
        n_a = sum(1 for s in sched._last_slots if s.device_type != "cpu")
        ta, tb = class_times(run.stats.times, n_a)
        assert run.stats.time_a == ta and run.stats.time_b == tb
        assert run.stats.time_a > 0 and run.stats.time_b > 0


class TestBalancerFaultIsolation:
    def test_observe_ignores_failed_stats(self):
        lb = LoadBalancer()
        rec = FaultRecord(slot=0, device="gpu0/q0", device_type="gpu",
                          kind="crash", attempt=0)
        bad = ExecutionStats(times=[1.0, 0.1], share_a=0.5, failures=[rec])
        for _ in range(10):
            assert not lb.observe(bad)
        assert lb.lbt == 0.0
        # the same (unbalanced) times without failures do trigger
        good = ExecutionStats(times=[1.0, 0.1], share_a=0.5)
        assert any(lb.observe(good) for _ in range(5))

    def test_kb_rejects_corrupt_best_time(self):
        kb = KnowledgeBase()
        p = Profile(sct_id="s", workload=Workload((8,)), share_a=0.5,
                    config=PlatformConfig(), best_time=float("nan"))
        with pytest.raises(ValueError):
            kb.store(p)


# ---------------------------------------------------------------------------
# Session / Future: context manager, request retry, identity-rich errors
# ---------------------------------------------------------------------------

class TestSessionFaults:
    def test_context_manager_and_retry_recovers(self):
        sct = saxpy_tree()
        inj = FaultInjector(crash_on_call={"gpu0": [1]})
        sched = make_scheduler(
            ThreadedExecutor(injector=inj,
                             policy=FaultPolicy(max_attempts=1)))
        with Session(sched) as sess:
            fut = sess.run(sct, retries=2, **saxpy_arrays())
            out = fut.get(timeout=60)
        x = saxpy_arrays()["x"]
        np.testing.assert_array_equal(out.outputs["z"], 2.0 * x + 1.0)

    def test_future_reraises_with_device_identity(self):
        sct = saxpy_tree()
        inj = FaultInjector(crash_prob=1.0)
        sched = make_scheduler(ThreadedExecutor(injector=inj))
        with Session(sched) as sess:
            fut = sess.run(sct, **saxpy_arrays())
            with pytest.raises(ExecutionError) as ei:
                fut.get(timeout=60)
        assert "gpu0" in str(ei.value) or "cpu0" in str(ei.value)
        assert ei.value.records

    def test_request_deadline(self):
        sct = saxpy_tree()
        inj = FaultInjector(stall_on_call={"cpu0": [1]}, stall_seconds=2.0)
        sched = make_scheduler(
            ThreadedExecutor(injector=inj, policy=FaultPolicy(
                max_attempts=1, default_deadline=None)))
        with Session(sched) as sess:
            fut = sess.run(sct, deadline=0.4, **saxpy_arrays())
            with pytest.raises(ExecutionError, match="did not complete"):
                fut.get()
