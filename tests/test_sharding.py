"""Logical-axis sharding rules + HLO analyzer units (no 512-device init:
these tests build tiny meshes from the single host device where needed,
or test the pure functions directly)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_analysis import (Analysis, analyze,
                                       parse_computations, shape_bytes,
                                       shape_numel)
from repro.launch.roofline import KIND_FACTOR, Roofline, roofline
from repro.models.sharding import Rules, spec_for


class FakeMesh:
    """Duck-typed mesh: spec_for only reads .shape (a dict)."""

    def __init__(self, **axes):
        self.shape = dict(axes)


RULES = Rules(table={
    "batch": (("pod", "data"),),
    "embed": (("pod", "data"),),
    "heads": ("model",),
    "vocab": ("model",),
    "mlp": ("model",),
})


class TestSpecFor:
    def test_divisible_dims_shard(self):
        mesh = FakeMesh(pod=2, data=16, model=16)
        spec = spec_for((256, 4096), ("batch", None), mesh, RULES)
        assert spec == P(("pod", "data"))

    def test_indivisible_falls_back_to_replicated(self):
        """The paper's 'relax the constraint' escape hatch."""
        mesh = FakeMesh(pod=2, data=16, model=16)
        spec = spec_for((49155, 64), ("vocab", None), mesh, RULES)
        assert spec == P()                      # 49155 % 16 != 0

    def test_no_axis_reuse_within_tensor(self):
        mesh = FakeMesh(pod=2, data=16, model=16)
        spec = spec_for((64, 32), ("heads", "mlp"), mesh, RULES)
        # both want 'model'; only the first gets it
        assert spec == P("model")

    def test_unknown_logical_replicated(self):
        mesh = FakeMesh(data=4, model=2)
        assert spec_for((8, 8), ("nope", None), mesh, RULES) == P()


class TestHloAnalysis:
    HLO = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%d), to_apply=%sum
  %c1 = s32[] constant(1)
  %iv2 = s32[] add(%iv, %c1)
  ROOT %t = (s32[], f32[8,8]) tuple(%iv2, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%iv, %c), direction=LT
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8] parameter(0)
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%c0, %x)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""

    def test_shape_bytes(self):
        assert shape_bytes("f32[8,8]") == 256
        assert shape_bytes("bf16[4,2]") == 16
        assert shape_bytes("(s32[], f32[2,2])") == 20
        assert shape_numel("f32[3,5]") == 15

    def test_loop_multiplied_flops_and_collectives(self):
        a = analyze(self.HLO)
        # 5 iterations x (2*8*8*8) dot flops (+ elementwise adds)
        assert a.flops == pytest.approx(5 * 1024, rel=0.05)
        assert a.collective_bytes["all-reduce"] == pytest.approx(5 * 256)
        assert a.collective_count["all-reduce"] == 5

    def test_computation_parse(self):
        comps = parse_computations(self.HLO)
        assert "__entry__" in comps
        assert "body" in comps and "cond" in comps


class TestRoofline:
    def test_terms_and_bottleneck(self):
        r = roofline(per_chip_flops=197e12, per_chip_hbm_bytes=819e9 / 2,
                     per_chip_collective_bytes=0, chips=256,
                     active_params=1e9, tokens=1e6, kind="train")
        assert r.compute_s == pytest.approx(1.0)
        assert r.memory_s == pytest.approx(0.5)
        assert r.bottleneck == "compute"
        assert r.step_time_s == pytest.approx(1.0)

    def test_model_flops_kinds(self):
        for kind, f in KIND_FACTOR.items():
            r = roofline(per_chip_flops=1, per_chip_hbm_bytes=1,
                         per_chip_collective_bytes=1, chips=2,
                         active_params=10, tokens=5, kind=kind)
            assert r.model_flops == f * 50

    def test_roofline_fraction_definition(self):
        r = roofline(per_chip_flops=197e12, per_chip_hbm_bytes=0,
                     per_chip_collective_bytes=0, chips=1,
                     active_params=1, tokens=197e12 / 6, kind="train")
        # model flops == hlo flops == chips*peak*step_time -> fraction 1
        assert r.roofline_fraction == pytest.approx(1.0)
