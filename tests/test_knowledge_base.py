"""Knowledge base: profile store, RBF/NN derivation, scope widening
(paper Sec. 3.2.1 / 3.2.3)."""
import math
import os

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import KnowledgeBase, Origin, PlatformConfig, Profile
from repro.core.knowledge_base import RBFNetwork, nearest_neighbour
from repro.core.spec import Workload


def prof(sct, dims, share, time=1.0, fission="L2", overlap=4):
    return Profile(sct_id=sct, workload=Workload(tuple(dims)),
                   share_a=share, best_time=time,
                   config=PlatformConfig(fission_level=fission,
                                         overlap=overlap))


class TestStore:
    def test_best_time_wins(self):
        kb = KnowledgeBase()
        kb.store(prof("p", (1024,), 0.8, time=2.0))
        kb.store(prof("p", (1024,), 0.9, time=1.0))
        kb.store(prof("p", (1024,), 0.5, time=3.0))   # worse: ignored
        assert kb.exact("p", Workload((1024,))).share_a == 0.9

    def test_persistence_roundtrip(self, tmp_path):
        path = os.path.join(tmp_path, "kb.json")
        kb = KnowledgeBase(path)
        kb.store(prof("p", (512, 512), 0.75))
        kb2 = KnowledgeBase(path)
        got = kb2.exact("p", Workload((512, 512)))
        assert got is not None and got.share_a == 0.75
        assert got.config.fission_level == "L2"


class TestRBF:
    def test_interpolates_exactly_at_nodes(self):
        x = np.array([[1.0], [2.0], [3.0]])
        y = np.array([10.0, 20.0, 15.0])
        net = RBFNetwork().fit(x, y)
        np.testing.assert_allclose(net.predict(x), y, atol=1e-3)

    def test_between_nodes_sane(self):
        x = np.array([[0.0], [1.0]])
        y = np.array([0.0, 1.0])
        p = float(RBFNetwork().fit(x, y).predict(np.array([0.5])))
        assert 0.2 < p < 0.8

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.floats(0.5, 13.5), min_size=3, max_size=8,
                    unique=True))
    def test_node_recovery_property(self, exps):
        # nodes spaced >= 0.25 in log space (coincident nodes make the
        # regularised solve interpolate their mean, which is correct
        # behaviour but not what this property asserts)
        exps = sorted(exps)
        exps = [e for i, e in enumerate(exps)
                if i == 0 or e - exps[i - 1] > 0.25]
        if len(exps) < 3:
            return
        x = np.exp(np.array(exps))[:, None]
        y = np.linspace(0, 1, len(x))
        net = RBFNetwork().fit(np.log1p(x), y)
        np.testing.assert_allclose(net.predict(np.log1p(x)), y, atol=5e-2)


class TestDerivation:
    def test_same_sct_scope_first(self):
        kb = KnowledgeBase()
        kb.store(prof("A", (1000,), 0.6))
        kb.store(prof("A", (4000,), 0.8))
        kb.store(prof("B", (2000,), 0.1))
        got = kb.derive("A", Workload((2000,)))
        assert got.origin is Origin.DERIVED
        assert 0.4 < got.share_a < 0.95      # from A's profiles, not B's

    def test_scope_widens_to_same_workload(self):
        kb = KnowledgeBase()
        kb.store(prof("B", (2000,), 0.33))
        got = kb.derive("A", Workload((2000,)))
        assert got is not None
        assert got.share_a == pytest.approx(0.33, abs=0.05)

    def test_empty_kb_returns_none(self):
        assert KnowledgeBase().derive("A", Workload((128,))) is None

    def test_nn_used_for_high_dims(self):
        kb = KnowledgeBase()
        kb.store(prof("A", (2, 3, 4, 5), 0.25, fission="L3"))
        kb.store(prof("A", (100, 100, 100, 100), 0.9, fission="L1"))
        got = kb.derive("A", Workload((3, 3, 4, 5)))
        assert got.share_a == 0.25            # nearest neighbour
        assert got.config.fission_level == "L3"

    def test_monotone_interpolation_tracks_size(self):
        """Table 5-style: derived share follows workload size trend."""
        kb = KnowledgeBase()
        for n, s in [(512, 0.5), (2048, 0.7), (8192, 0.9)]:
            kb.store(prof("img", (n, n), s))
        small = kb.derive("img", Workload((700, 700))).share_a
        large = kb.derive("img", Workload((6000, 6000))).share_a
        assert small < large


def test_nearest_neighbour_log_scale():
    pts = np.array([[1000.0], [1_000_000.0]])
    assert nearest_neighbour(np.array([2000.0]), pts) == 0
    assert nearest_neighbour(np.array([400_000.0]), pts) == 1
