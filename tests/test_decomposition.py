"""Locality-aware domain decomposition (paper Sec. 3.1) — unit + property.

The constraint system under test, for every vector V and kernels K1, K2
sharing it:  epu(V) % nu(V,K) == 0,  #V^j % (epu/nu) == 0,
#V^j % wgs_j(K) == 0, and the partitions tile the domain exactly.
"""
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (DecompositionError, ExecutionSlot, KernelSpec,
                        Pipeline, build_plan, kernel, scalar, validate,
                        vector)
from repro.core.spec import Transfer


def two_kernel_pipeline(epu=4, nu=2, copy_weights=False):
    k1 = kernel(lambda x: x * 2, name="k1",
                inputs=[vector("x", epu=epu)],
                outputs=[vector("mid", epu=epu)],
                work_per_thread=nu)
    k2_in = [vector("mid", epu=epu)]
    if copy_weights:
        k2_in.append(vector("w", copy=True))
    k2 = kernel(lambda m, *a: m + 1, name="k2", inputs=k2_in,
                outputs=[vector("y", epu=epu)], work_per_thread=nu)
    return Pipeline(k1, k2)


class TestBuildPlan:
    def test_shared_edge_units(self):
        sct = two_kernel_pipeline(epu=4)
        plan = build_plan(sct, {"x": (64,), "mid": (64,), "y": (64,)})
        assert plan.domain_units == 16
        assert not plan.vectors["x"].copy

    def test_copy_vectors_replicated(self):
        sct = two_kernel_pipeline(epu=4, copy_weights=True)
        plan = build_plan(sct, {"x": (64,), "mid": (64,), "y": (64,),
                                "w": (10,)})
        assert plan.vectors["w"].copy

    def test_locality_violation_rejected(self):
        """Vectors disagreeing on unit count cannot share a tree."""
        k1 = kernel(lambda x: x, name="k1", inputs=[vector("x", epu=4)],
                    outputs=[vector("mid", epu=4)])
        k2 = kernel(lambda m: m, name="k2", inputs=[vector("mid", epu=8)],
                    outputs=[vector("y", epu=8)])
        with pytest.raises(DecompositionError):
            build_plan(Pipeline(k1, k2), {"x": (64,), "mid": (64,),
                                          "y": (64,)})

    def test_extent_not_multiple_of_epu(self):
        sct = two_kernel_pipeline(epu=5)
        with pytest.raises(DecompositionError):
            build_plan(sct, {"x": (64,), "mid": (64,), "y": (64,)})

    def test_epu_not_multiple_of_nu(self):
        sct = two_kernel_pipeline(epu=3, nu=2)
        plan = build_plan(sct, {"x": (63,), "mid": (63,), "y": (63,)})
        slots = [ExecutionSlot("d0", "gpu")]
        with pytest.raises(DecompositionError):
            plan.partition(slots, [1.0])


class TestPartition:
    def test_even_split_validates(self):
        sct = two_kernel_pipeline(epu=4)
        plan = build_plan(sct, {"x": (64,), "mid": (64,), "y": (64,)})
        slots = [ExecutionSlot("g0", "gpu", wgs={"k1": 8, "k2": 8}),
                 ExecutionSlot("c0", "cpu", wgs={"k1": 8, "k2": 8})]
        part = plan.partition(slots, [0.5, 0.5])
        validate(plan, part)
        assert sum(part.sizes("x")) == 64

    def test_uneven_shares_quantised(self):
        sct = two_kernel_pipeline(epu=4)
        plan = build_plan(sct, {"x": (64,), "mid": (64,), "y": (64,)})
        slots = [ExecutionSlot("g0", "gpu", wgs={"k1": 8, "k2": 8}),
                 ExecutionSlot("c0", "cpu", wgs={"k1": 4, "k2": 4})]
        part = plan.partition(slots, [0.7, 0.3])
        validate(plan, part)
        assert sum(part.units) == plan.domain_units

    def test_slices_tile_domain(self):
        sct = two_kernel_pipeline(epu=2)
        plan = build_plan(sct, {"x": (32,), "mid": (32,), "y": (32,)})
        slots = [ExecutionSlot(f"d{i}", "gpu") for i in range(3)]
        part = plan.partition(slots, [0.5, 0.3, 0.2])
        xs = jnp.arange(32.0)
        pieces = part.slices("x", xs)
        assert jnp.concatenate(pieces).tolist() == xs.tolist()


@settings(max_examples=60, deadline=None)
@given(
    units=st.integers(4, 200),
    epu=st.sampled_from([1, 2, 4, 8]),
    n_slots=st.integers(1, 6),
    seed=st.integers(0, 2 ** 31),
)
def test_partition_properties(units, epu, n_slots, seed):
    """Property: any share vector yields a tiling, quantised partitioning
    covering the domain exactly (paper constraint 1: V = U_j V^j)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    raw = rng.random(n_slots) + 1e-3
    shares = (raw / raw.sum()).tolist()
    shares[-1] = 1.0 - sum(shares[:-1])

    extent = units * epu
    sct = two_kernel_pipeline(epu=epu, nu=1)
    plan = build_plan(sct, {"x": (extent,), "mid": (extent,),
                            "y": (extent,)})
    slots = [ExecutionSlot(f"d{i}", "gpu" if i % 2 else "cpu")
             for i in range(n_slots)]
    part = plan.partition(slots, shares)
    assert sum(part.units) == plan.domain_units
    assert sum(part.sizes("x")) == extent
    offs = part.offsets("x")
    szs = part.sizes("x")
    for i in range(1, n_slots):
        assert offs[i] == offs[i - 1] + szs[i - 1]
    if not part.relaxed:
        validate(plan, part)
