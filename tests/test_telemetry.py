"""Telemetry subsystem (ISSUE 8): tracing, metrics, events, integration.

Covers the primitives (nested spans → Chrome B/E pairs, virtual-clock
spans, metrics registry + Prometheus dump, event ring buffer + logging
bridge), the no-op fast path (microbench bound), the instrumented
pipeline (run/plan/dispatch/slot/merge spans, fault + repartition
events, quarantine warnings), the Session surface
(``metrics``/``counters``/``export_trace``), determinism under the
simulator, and the ``ExecutionStats.overhead_seconds`` invariants
satellite.
"""
import itertools
import json
import logging
import math
import threading
import time

import numpy as np
import pytest

from repro.core import (AcceleratorPlatform, DeviceInfo, FaultInjector,
                        FaultPolicy, HostPlatform, KnowledgeBase,
                        LoadBalancer, NULL_TELEMETRY, PlatformConfig, Profile,
                        Scheduler, Session, SimDevice, SimulatedExecutor,
                        Telemetry, ThreadedExecutor, Tracer,
                        validate_chrome_trace)
from repro.core.faults import DeviceHealth
from repro.core.load_balancer import ExecutionStats
from repro.core.telemetry import (EventLog, MetricsRegistry, metrics_block)
from repro.core import kernel, scalar, vector

POLICY = FaultPolicy(watchdog_multiple=1e6)   # no spurious watchdog on CI


def counting_clock(step: float = 1.0):
    c = itertools.count()
    return lambda: next(c) * step


def saxpy_tree():
    return kernel(lambda a, x, y: a * x + y, name="saxpy",
                  inputs=[scalar("a"), vector("x"), vector("y")],
                  outputs=[vector("z")])


def chain_trees():
    k2 = kernel(lambda a, z: z * a, name="scale",
                inputs=[scalar("a"), vector("z")], outputs=[vector("w")])
    return [saxpy_tree(), k2]


def saxpy_arrays(n=256, a=2.0):
    return {"a": np.float32(a),
            "x": np.arange(n, dtype=np.float32),
            "y": np.ones(n, dtype=np.float32)}


def make_scheduler(executor, **kw):
    host = HostPlatform(DeviceInfo("cpu0", "cpu", compute_units=4),
                        topology={"L2": 2, "NO_FISSION": 1})
    accel = AcceleratorPlatform([DeviceInfo("gpu0", "gpu")], max_overlap=2)
    kw.setdefault("balancer", LoadBalancer(max_dev=0.0))
    kw.setdefault("kb", KnowledgeBase())
    return Scheduler(host=host, accel=accel, executor=executor, **kw)


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

class TestTracer:
    def test_nested_spans_emit_matched_be_pairs(self):
        tr = Tracer(clock=counting_clock())
        with tr.span("outer"):
            with tr.span("inner"):
                pass
        evs = tr.events()
        assert [(e["name"], e["ph"]) for e in evs] == \
            [("outer", "B"), ("inner", "B"), ("inner", "E"), ("outer", "E")]
        assert all(e["ts"] >= 0 for e in evs)

    def test_span_attrs_and_late_notes(self):
        tr = Tracer(clock=counting_clock())
        with tr.span("plan", slots=3) as sp:
            sp.note(cache_hit=True)
        b, e = tr.events()
        assert b["args"] == {"slots": 3}
        assert e["args"] == {"cache_hit": True}

    def test_exception_annotates_and_closes_span(self):
        tr = Tracer(clock=counting_clock())
        with pytest.raises(ValueError):
            with tr.span("boom"):
                raise ValueError("x")
        b, e = tr.events()
        assert e["ph"] == "E" and e["args"]["error"] == "ValueError"
        assert validate_chrome_trace(tr.chrome_trace()) == []

    def test_instant_and_virtual_record(self):
        tr = Tracer(clock=counting_clock())
        tr.instant("marker", reason="test")
        tr.record("slot", 100.0, 50.0, tid=7, device="gpu0")
        inst, x = tr.events()
        assert inst["ph"] == "i"
        assert x == {"name": "slot", "ph": "X", "ts": 100.0, "dur": 50.0,
                     "pid": 0, "tid": 7, "args": {"device": "gpu0"}}
        assert validate_chrome_trace(tr.chrome_trace()) == []

    def test_threads_get_distinct_tids(self):
        tr = Tracer()

        def spin():
            with tr.span("t"):
                time.sleep(0.01)

        threads = [threading.Thread(target=spin) for _ in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        tids = {e["tid"] for e in tr.events()}
        assert len(tids) == 3
        assert validate_chrome_trace(tr.chrome_trace()) == []

    def test_open_spans_closed_at_export(self):
        tr = Tracer(clock=counting_clock())
        sp = tr.span("dangling")
        sp.__enter__()                       # never exited
        trace = tr.chrome_trace()
        assert validate_chrome_trace(trace) == []
        closing = trace["traceEvents"][-1]
        assert closing["ph"] == "E" and closing["args"]["unterminated"]

    def test_capacity_bound_drops_excess(self):
        tr = Tracer(clock=counting_clock(), capacity=4)
        for _ in range(5):
            with tr.span("s"):
                pass
        assert len(tr.events()) == 4
        assert tr.dropped == 6


# ---------------------------------------------------------------------------
# Chrome-trace validation
# ---------------------------------------------------------------------------

class TestValidation:
    def test_detects_unmatched_b(self):
        trace = {"traceEvents": [
            {"name": "a", "ph": "B", "ts": 0, "pid": 0, "tid": 0}]}
        assert any("unmatched B" in e for e in validate_chrome_trace(trace))

    def test_detects_mismatched_nesting(self):
        trace = {"traceEvents": [
            {"name": "a", "ph": "B", "ts": 0, "pid": 0, "tid": 0},
            {"name": "b", "ph": "E", "ts": 1, "pid": 0, "tid": 0}]}
        assert any("mismatched" in e for e in validate_chrome_trace(trace))

    def test_detects_missing_keys_and_bad_x(self):
        trace = {"traceEvents": [
            {"name": "a", "ph": "X", "ts": 0, "pid": 0, "tid": 0},
            {"ph": "i", "ts": 0, "pid": 0, "tid": 0}]}
        errs = validate_chrome_trace(trace)
        assert any("dur" in e for e in errs)
        assert any("missing keys" in e for e in errs)

    def test_rejects_non_object(self):
        assert validate_chrome_trace([]) == ["trace is not a JSON object"]
        assert validate_chrome_trace({}) == \
            ["traceEvents missing or not a list"]


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_counter_inc_and_snapshot(self):
        reg = MetricsRegistry()
        reg.counter("runs_total").inc()
        reg.counter("runs_total").inc(2)
        assert reg.snapshot() == {"runs_total": 3.0}

    def test_labelled_series_are_distinct(self):
        reg = MetricsRegistry()
        reg.counter("busy", device="gpu0").inc(1.5)
        reg.counter("busy", device="cpu0").inc(0.5)
        snap = reg.snapshot()
        assert snap["busy{device=gpu0}"] == 1.5
        assert snap["busy{device=cpu0}"] == 0.5

    def test_gauge_and_histogram(self):
        reg = MetricsRegistry()
        reg.gauge("lbt").set(0.75)
        h = reg.histogram("lat", buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 5.0):
            h.observe(v)
        snap = reg.snapshot()
        assert snap["lbt"] == 0.75
        assert snap["lat"]["count"] == 3
        assert snap["lat"]["sum"] == pytest.approx(5.55)
        assert snap["lat"]["buckets"] == {"0.1": 1, "1.0": 2, "+Inf": 3}

    def test_prometheus_dump(self):
        reg = MetricsRegistry()
        reg.counter("runs_total", status="ok").inc(4)
        reg.histogram("lat", buckets=(1.0,)).observe(0.5)
        text = reg.to_prometheus()
        assert "# TYPE runs_total counter" in text
        assert 'runs_total{status="ok"} 4.0' in text
        assert 'lat_bucket{le="1.0"} 1' in text
        assert "lat_count 1" in text


# ---------------------------------------------------------------------------
# Event log
# ---------------------------------------------------------------------------

class TestEventLog:
    def test_ring_buffer_capacity(self):
        log = EventLog(capacity=3, bridge=False)
        for i in range(5):
            log.emit("e", i=i)
        assert [e.fields["i"] for e in log.records()] == [2, 3, 4]
        assert log.records()[-1].seq == 4

    def test_sink_called_and_broken_sink_contained(self):
        seen = []
        log = EventLog(bridge=False, sink=seen.append)
        log.add_sink(lambda e: 1 / 0)     # must not propagate
        ev = log.emit("fault", device="gpu0")
        assert seen == [ev]
        assert ev.fields == {"device": "gpu0"}

    def test_kind_prefix_filter(self):
        log = EventLog(bridge=False)
        log.emit("health.quarantined")
        log.emit("health.reinstated")
        log.emit("fault")
        assert len(log.records("health")) == 2

    def test_logging_bridge(self, caplog):
        log = EventLog()
        with caplog.at_level(logging.INFO, logger="repro.telemetry"):
            log.emit("balancer.trigger", level="info", lbt=0.95)
        assert any("balancer.trigger" in r.message for r in caplog.records)

    def test_disabled_log_buffers_nothing_but_bridges_warnings(self, caplog):
        log = NULL_TELEMETRY.events
        with caplog.at_level(logging.WARNING, logger="repro.telemetry"):
            log.emit("health.quarantined", level="warning",
                     message="device gpu0 quarantined", device="gpu0")
            log.emit("plan_cache.invalidated")      # info: not bridged
        assert len(log) == 0
        msgs = [r.message for r in caplog.records]
        assert any("gpu0 quarantined" in m for m in msgs)
        assert not any("plan_cache" in m for m in msgs)


# ---------------------------------------------------------------------------
# No-op fast path
# ---------------------------------------------------------------------------

class TestNoOpCost:
    def test_null_span_is_shared_singleton(self):
        t = NULL_TELEMETRY.tracer
        assert t.span("a", x=1) is t.span("b")      # no allocation
        assert NULL_TELEMETRY.metrics.counter("c") is \
            NULL_TELEMETRY.metrics.gauge("g")

    def test_noop_span_microbench(self):
        # acceptance: disabled telemetry must show no measurable overhead.
        # The shared no-op span costs ~0.3µs/span on this container; the
        # bound is loose for noisy CI but still orders of magnitude under
        # a real span.
        tracer = NULL_TELEMETRY.tracer
        n = 50_000
        t0 = time.perf_counter()
        for _ in range(n):
            with tracer.span("slot", device="gpu0/q0", units=128):
                pass
        per_span = (time.perf_counter() - t0) / n
        assert per_span < 5e-6, f"no-op span costs {per_span * 1e6:.2f}µs"

    def test_disabled_pipeline_records_nothing(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        sched.run(saxpy_tree(), saxpy_arrays())
        assert sched.telemetry is NULL_TELEMETRY
        assert sched.telemetry.tracer.events() == []
        assert sched.telemetry.metrics.snapshot() == {}


# ---------------------------------------------------------------------------
# Instrumented pipeline
# ---------------------------------------------------------------------------

class TestPipelineTracing:
    def test_run_trace_contains_span_model(self, tmp_path):
        tel = Telemetry()
        sched = make_scheduler(ThreadedExecutor(policy=POLICY),
                               telemetry=tel)
        sched.run(saxpy_tree(), saxpy_arrays())
        trace = tel.export_trace(str(tmp_path / "trace.json"))
        assert validate_chrome_trace(trace) == []
        names = {e["name"] for e in trace["traceEvents"]}
        assert {"run", "plan", "dispatch", "attempt", "slot",
                "merge"} <= names

    def test_fault_injected_chain_trace(self, tmp_path):
        # acceptance: 2-SCT fault-injected run_chain yields a valid trace
        # with plan, per-slot compute, retry and merge spans
        tel = Telemetry()
        inj = FaultInjector(crash_on_call={"gpu0": [1]})
        sched = make_scheduler(ThreadedExecutor(policy=POLICY, injector=inj),
                               telemetry=tel)
        with Session(sched) as s:
            runs = s.run_chain(chain_trees(), **saxpy_arrays()).get()
            path = tmp_path / "trace.json"
            s.export_trace(str(path))
        trace = json.loads(path.read_text())
        assert validate_chrome_trace(trace) == []
        names = {e["name"] for e in trace["traceEvents"]}
        assert {"run", "plan", "slot", "merge"} <= names
        retry_spans = [e for e in trace["traceEvents"]
                       if e["name"] == "attempt"
                       and e.get("args", {}).get("attempt", 0) >= 1]
        assert retry_spans, "retry attempt span missing"
        assert sum(r.stats.retries for r in runs) >= 1
        kinds = {e.kind for e in tel.events.records()}
        assert {"fault", "retry.repartition"} <= kinds

    def test_session_metrics_match_execution_stats(self):
        tel = Telemetry()
        inj = FaultInjector(crash_on_call={"gpu0": [2]})
        sched = make_scheduler(ThreadedExecutor(policy=POLICY, injector=inj),
                               telemetry=tel)
        stats = []
        with Session(sched) as s:
            for _ in range(3):
                stats.append(s.run(saxpy_tree(), **saxpy_arrays())
                             .get().stats)
            m = s.metrics()
        assert m["retries_total"] == sum(st.retries for st in stats)
        hits = m.get("plan_cache_hits_total", 0)
        misses = m.get("plan_cache_misses_total", 0)
        assert hits + misses == len(stats)
        assert hits / (hits + misses) == \
            pytest.approx(sched.plan_cache.hit_rate)
        assert m["merge_bytes_total"] == \
            sum(st.merge_bytes for st in stats)
        assert m["runs_total{status=ok}"] == \
            sum(1 for st in stats if st.ok)

    def test_device_busy_seconds_accounted(self):
        tel = Telemetry()
        sched = make_scheduler(ThreadedExecutor(policy=POLICY),
                               telemetry=tel)
        sched.run(saxpy_tree(), saxpy_arrays())
        m = tel.metrics.snapshot()
        assert m.get("device_busy_seconds_total{device=gpu0}", 0) > 0
        assert m.get("device_busy_seconds_total{device=cpu0}", 0) > 0

    def test_plan_cache_invalidation_event(self):
        tel = Telemetry()
        sched = make_scheduler(ThreadedExecutor(policy=POLICY),
                               telemetry=tel,
                               balancer=LoadBalancer(max_dev=1.5,
                                                     weight=1.0))
        sched.run(saxpy_tree(), saxpy_arrays())
        r = sched.run(saxpy_tree(), saxpy_arrays())   # forced "adjusted"
        assert r.action == "adjusted"
        evs = tel.events.records("plan_cache.invalidated")
        assert evs and evs[0].fields["reason"] == "share adjustment"
        assert tel.metrics.snapshot()[
            "plan_cache_invalidations_total"] >= 1

    def test_balancer_trigger_and_adjust_events(self):
        tel = Telemetry()
        sched = make_scheduler(ThreadedExecutor(policy=POLICY),
                               telemetry=tel,
                               balancer=LoadBalancer(max_dev=1.5,
                                                     weight=1.0))
        sched.run(saxpy_tree(), saxpy_arrays())
        sched.run(saxpy_tree(), saxpy_arrays())
        kinds = [e.kind for e in tel.events.records()]
        assert "balancer.trigger" in kinds
        assert "balancer.adjust" in kinds
        adj = tel.events.records("balancer.adjust")[0]
        assert {"share_a_before", "share_a_after"} <= set(adj.fields)


# ---------------------------------------------------------------------------
# Counters satellite
# ---------------------------------------------------------------------------

class TestCounters:
    def test_scheduler_counters_namespaced(self):
        inj = FaultInjector(crash_on_call={"gpu0": [2]})
        ex = ThreadedExecutor(policy=POLICY, injector=inj)
        sched = make_scheduler(ex)
        for _ in range(3):
            sched.run(saxpy_tree(), saxpy_arrays())
        c = sched.counters()
        assert c["plan_cache.hits"] == sched.plan_cache.hits
        assert c["plan_cache.misses"] == sched.plan_cache.misses
        assert c["scheduler.runs"] == 3
        assert c["scheduler.retries"] == 1
        assert c["executor.pools_created"] == ex.pools_created
        assert c["executor.pool_reuses"] == ex.pool_reuses
        assert "balancer.balance_ops" in c
        assert "health.quarantined" in c

    def test_session_reexports_counters_and_resident_handoffs(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        with Session(sched) as s:
            s.run_chain(chain_trees(), **saxpy_arrays()).get()
            c = s.counters()
        assert c["scheduler.resident_handoffs"] == 1    # first chain step
        assert c["scheduler.runs"] == 2


# ---------------------------------------------------------------------------
# Quarantine logging satellite
# ---------------------------------------------------------------------------

class TestHealthLogging:
    def test_quarantine_warning_logged_without_telemetry(self, caplog):
        h = DeviceHealth(quarantine_after=2)
        with caplog.at_level(logging.WARNING, logger="repro.telemetry"):
            h.record_failure("gpu0")
            assert not caplog.records          # below threshold: silent
            h.record_failure("gpu0")
        msgs = [r.message for r in caplog.records]
        assert any("gpu0" in m and "2 consecutive failures" in m
                   for m in msgs)
        assert all(r.levelno == logging.WARNING for r in caplog.records)

    def test_reinstatement_warning_logged(self, caplog):
        h = DeviceHealth(quarantine_after=1)
        h.record_failure("gpu0")
        with caplog.at_level(logging.WARNING, logger="repro.telemetry"):
            h.record_success("gpu0")
        assert any("gpu0" in r.message and "reinstated" in r.message
                   for r in caplog.records)

    def test_quarantine_events_and_metrics_with_telemetry(self):
        tel = Telemetry()
        h = DeviceHealth(quarantine_after=1)
        h.telemetry = tel
        h.record_failure("gpu0")
        h.record_success("gpu0")
        kinds = [e.kind for e in tel.events.records()]
        assert kinds == ["health.quarantined", "health.reinstated"]
        m = tel.metrics.snapshot()
        assert m["quarantines_total"] == 1
        assert m["reinstatements_total"] == 1
        assert m["device_failures_total{device=gpu0}"] == 1


# ---------------------------------------------------------------------------
# Simulator determinism
# ---------------------------------------------------------------------------

class TestSimulatorTelemetry:
    def _run(self):
        tel = Telemetry(clock=counting_clock())
        inj = FaultInjector(crash_on_call={"gpu0": [1]})
        ex = SimulatedExecutor([SimDevice("gpu0", "gpu", flops=1e12),
                                SimDevice("cpu0", "cpu", flops=1e11,
                                          cores=4)],
                               seed=7, injector=inj)
        sched = make_scheduler(ex, telemetry=tel)
        for _ in range(3):
            sched.run(saxpy_tree(), saxpy_arrays())
        return tel

    def test_trace_is_deterministic(self):
        t1, t2 = self._run(), self._run()
        assert t1.tracer.chrome_trace()["traceEvents"] == \
            t2.tracer.chrome_trace()["traceEvents"]
        # overhead histograms time the host-side scheduler with the real
        # perf_counter even under the simulator; everything derived from
        # simulated stats.times must be bit-identical
        def sim_metrics(t):
            return {k: v for k, v in t.metrics.snapshot().items()
                    if not k.startswith("overhead_seconds")}
        assert sim_metrics(t1) == sim_metrics(t2)
        assert [e.kind for e in t1.events.records()] == \
            [e.kind for e in t2.events.records()]

    def test_simulated_slots_on_virtual_timeline(self):
        tel = self._run()
        trace = tel.tracer.chrome_trace()
        assert validate_chrome_trace(trace) == []
        xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
        assert xs and all(e["name"] == "slot" for e in xs)
        # fault-injected slot annotated; the retry round starts on the
        # virtual clock only after the faulted round completes (all slots
        # in a round share ts = round start)
        assert any(e["args"].get("fault") == "crash" for e in xs)
        retry = [e for e in xs if e["args"]["attempt"] == 1]
        assert retry
        round0_ts = min(e["ts"] for e in xs)
        round0_end = round0_ts + max(e["dur"] for e in xs
                                     if e["ts"] == round0_ts)
        assert min(e["ts"] for e in retry) >= round0_end


# ---------------------------------------------------------------------------
# Overhead-breakdown invariants satellite
# ---------------------------------------------------------------------------

class TestOverheadInvariants:
    @pytest.mark.parametrize("plan_cache", [True, False])
    @pytest.mark.parametrize("persistent_pool", [True, False])
    def test_components_nonnegative_and_bounded(self, plan_cache,
                                                persistent_pool):
        sched = make_scheduler(
            ThreadedExecutor(policy=POLICY,
                             persistent_pool=persistent_pool),
            plan_cache=plan_cache)
        for _ in range(2):                      # cold + warm paths
            t0 = time.perf_counter()
            r = sched.run(saxpy_tree(), saxpy_arrays())
            wall = time.perf_counter() - t0
            s = r.stats
            components = (s.plan_seconds, s.pool_seconds,
                          s.dispatch_seconds, s.merge_seconds)
            assert all(c >= 0 for c in components)
            assert s.compute_seconds >= 0
            assert s.overhead_seconds == pytest.approx(sum(components))
            # components are disjoint sub-intervals of the scheduled run
            assert s.overhead_seconds + s.compute_seconds <= wall + 5e-3

    def test_stats_histogram_recorded(self):
        tel = Telemetry()
        sched = make_scheduler(ThreadedExecutor(policy=POLICY),
                               telemetry=tel)
        sched.run(saxpy_tree(), saxpy_arrays())
        snap = tel.metrics.snapshot()
        assert snap["overhead_seconds"]["count"] == 1
        assert snap["class_makespan_seconds{cls=a}"]["count"] == 1
        assert snap["class_makespan_seconds{cls=b}"]["count"] == 1


# ---------------------------------------------------------------------------
# Snapshot / embedding helpers
# ---------------------------------------------------------------------------

class TestExport:
    def test_metrics_block_schema(self):
        tel = Telemetry()
        tel.metrics.counter("runs_total").inc()
        block = metrics_block(tel)
        assert block["schema"] == "repro.metrics/v1"
        assert block["enabled"] is True
        assert block["metrics"] == {"runs_total": 1.0}
        json.dumps(block)                       # JSON-serialisable

    def test_telemetry_snapshot_serialisable(self):
        tel = Telemetry()
        tel.events.emit("fault", level="warning", device="gpu0")
        tel.metrics.histogram("lat").observe(0.1)
        json.dumps(tel.snapshot())

    def test_export_trace_writes_valid_json_file(self, tmp_path):
        tel = Telemetry()
        with tel.tracer.span("run"):
            pass
        path = tmp_path / "t.json"
        tel.export_trace(str(path))
        assert validate_chrome_trace(json.loads(path.read_text())) == []
