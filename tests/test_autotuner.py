"""Algorithm 1 (profile construction) on the calibrated simulator
(paper Sec. 3.2.2, Fig. 5)."""
import math

import pytest

from repro.core import (AcceleratorPlatform, DeviceInfo, HostPlatform,
                        KnowledgeBase, TunerParams, build_profile)
from repro.core.knowledge_base import PlatformConfig
from repro.core.distribution import Distribution
from repro.core.spec import Workload


def analytic_evaluator(best_fission="L2", best_overlap=3, opt_share=0.7):
    """Convex synthetic landscape with a known optimum."""
    fission_rank = {"L1": 1, "L2": 0, "L3": 1, "NUMA": 2, "NO_FISSION": 3}

    def evaluate(cfg: PlatformConfig, dist: Distribution):
        base = 1.0
        base += 0.08 * abs(fission_rank[cfg.fission_level]
                           - fission_rank[best_fission])
        base += 0.05 * abs(cfg.overlap - best_overlap)
        base += 1.5 * (dist.a - opt_share) ** 2
        ta = base * dist.a / opt_share
        tb = base * dist.b / (1 - opt_share)
        return max(ta, tb), ta, tb

    return evaluate


def platforms():
    host = HostPlatform(DeviceInfo("cpu", "cpu", compute_units=16),
                        topology={"L1": 16, "L2": 8, "L3": 2, "NUMA": 1,
                                  "NO_FISSION": 1})
    accel = AcceleratorPlatform([DeviceInfo("gpu", "gpu")], max_overlap=6)
    return host, accel


class TestAlgorithm1:
    def test_finds_known_optimum(self):
        host, accel = platforms()
        res = build_profile("sct", Workload((1 << 20,)), host=host,
                            accel=accel, evaluate=analytic_evaluator(),
                            params=TunerParams(precision=1e-4,
                                               number_executions=1))
        assert res.profile.config.fission_level == "L2"
        assert res.profile.share_a == pytest.approx(0.7, abs=0.1)
        assert res.profile.best_time < math.inf

    def test_search_is_pruned(self):
        """Discard-on-no-improvement: far fewer evals than the full grid."""
        host, accel = platforms()
        res = build_profile("sct", Workload((1 << 18,)), host=host,
                            accel=accel, evaluate=analytic_evaluator(),
                            params=TunerParams(precision=1e-3,
                                               number_executions=1,
                                               max_distribution_iters=8))
        full_grid = 5 * 6 * 12 * 8
        assert res.evaluations < full_grid / 3

    def test_trace_is_fig5_material(self):
        host, accel = platforms()
        res = build_profile("sct", Workload((1 << 16,)), host=host,
                            accel=accel, evaluate=analytic_evaluator(),
                            params=TunerParams(number_executions=1))
        assert len(res.trace) == res.evaluations
        assert all(t.time > 0 for t in res.trace)

    def test_persists_to_kb(self):
        host, accel = platforms()
        kb = KnowledgeBase()
        build_profile("sct", Workload((4096,)), host=host, accel=accel,
                      evaluate=analytic_evaluator(), kb=kb,
                      params=TunerParams(number_executions=1))
        assert kb.exact("sct", Workload((4096,))) is not None
