"""Graph-admission fairness (ISSUE 10 satellite).

With ``max_inflight=2`` and five queued graphs, admission is FIFO:
the first two graphs run while the other three stay pending, each
completion admits exactly the next graph in submission order, and
``drain()`` observes every handle terminal.

The executor uses a wide shared pool (``max_workers``) rather than the
default single-worker per-device queues, so a deliberately blocked
graph never wedges another inflight graph's slot tasks — the release
order below then forces a deterministic settlement order.
"""
import threading

import numpy as np

from repro.core import JobGraph, ThreadedExecutor, kernel, vector

from test_graph import POLICY, make_scheduler


def gated_graph(i, event):
    sct = kernel(lambda x, ev=event: ev.wait(20) and x + 1.0,
                 name=f"gate{i}", inputs=[vector("x")],
                 outputs=[vector(f"z{i}")])
    g = JobGraph()
    g.add(sct, name="n")
    return g


class TestAdmissionFairness:
    def test_fifo_settlement_order_and_drain_terminal(self):
        events = [threading.Event() for _ in range(5)]
        order = []
        sched = make_scheduler(
            ThreadedExecutor(policy=POLICY, max_workers=32),
            max_inflight=2)
        try:
            x = np.arange(128, dtype=np.float32)
            handles = []
            for i in range(5):
                h = sched.submit(gated_graph(i, events[i]), {"x": x})
                h.add_done_callback(lambda _h, i=i: order.append(i))
                handles.append(h)
            # backpressure: only the first two graphs are admitted
            import time
            time.sleep(0.3)
            assert not any(h.done() for h in handles)
            for h in handles[2:]:
                assert set(h.status().values()) == {"pending"}
            # release in submission order; each completion admits the
            # next queued graph
            for i in range(5):
                events[i].set()
                assert handles[i].wait(20)
                if i + 2 < len(handles):
                    assert not handles[i + 2].done()
            assert order == [0, 1, 2, 3, 4]
            assert sched.drain(20)
            for i, h in enumerate(handles):
                assert h.done()
                assert set(h.status().values()) == {"done"}
                np.testing.assert_array_equal(
                    h.result(0).outputs[f"z{i}"], x + 1.0)
        finally:
            for ev in events:
                ev.set()
            sched.close()

    def test_drain_with_unblocked_burst(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY),
                               max_inflight=2)
        try:
            x = np.arange(256, dtype=np.float32)
            handles = []
            for i in range(5):
                sct = kernel(lambda x: x * 2.0, name=f"dbl{i}",
                             inputs=[vector("x")],
                             outputs=[vector(f"z{i}")])
                g = JobGraph()
                g.add(sct, name="n")
                handles.append(sched.submit(g, {"x": x}))
            assert sched.drain(30)
            for i, h in enumerate(handles):
                assert h.done()
                np.testing.assert_array_equal(
                    h.result(0).outputs[f"z{i}"], x * 2.0)
        finally:
            sched.close()
