"""Per-architecture smoke tests: reduced same-family config, one forward
+ one train step on CPU, asserting output shapes and no NaNs (assignment
requirement), plus prefill/decode consistency for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import arch_names, get_config, get_smoke
from repro.models import (decode_step, forward_train, init_cache, init_tree,
                          model_defs, prefill)
from repro.optim import AdamW, AdamWConfig
from repro.runtime import RuntimeConfig, init_state, make_train_step

ARCHS = arch_names()


def make_inputs(cfg, B=2, S=24, seed=0):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    extras = {}
    if cfg.enc_dec:
        extras["frames"] = jax.random.normal(
            jax.random.fold_in(key, 1), (B, cfg.enc_frames, cfg.d_model),
            jnp.bfloat16)
    elif cfg.frontend_positions:
        extras["frontend_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (B, cfg.frontend_positions, cfg.d_model), jnp.bfloat16)
    return tokens, extras


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = get_smoke(arch)
    params = init_tree(jax.random.PRNGKey(0), model_defs(cfg))
    tokens, extras = make_inputs(cfg)
    logits, aux = forward_train(params, cfg, tokens, **extras)
    assert logits.shape == (2, 24, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert not bool(jnp.isnan(aux).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_smoke(arch)
    params = init_tree(jax.random.PRNGKey(0), model_defs(cfg))
    opt = AdamW(AdamWConfig(lr=1e-3))
    state = init_state(params, opt)
    step = jax.jit(make_train_step(cfg, opt, RuntimeConfig(remat="dots")))
    tokens, extras = make_inputs(cfg)
    labels = jnp.where(jnp.arange(24)[None] == 23, -1,
                       jnp.roll(tokens, -1, axis=1))
    batch = {"tokens": tokens, "labels": labels, **extras}
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(state.opt.step) == 1
    # params actually moved
    before = jax.tree.leaves(params)[0]
    after = jax.tree.leaves(state.params)[0]
    assert not np.allclose(np.asarray(before, np.float32),
                           np.asarray(after, np.float32))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """decode_step(token S-1) must match forward_train logits at S-1."""
    cfg = get_smoke(arch)
    params = init_tree(jax.random.PRNGKey(0), model_defs(cfg))
    S = 16
    tokens, extras = make_inputs(cfg, S=S, seed=1)
    logits, _ = forward_train(params, cfg, tokens, **extras)
    lp, cache = prefill(params, cfg, tokens[:, :S - 1], capacity=S + 4,
                        **extras)
    ld, _ = decode_step(params, cfg, cache, tokens[:, S - 1],
                        jnp.asarray(S - 1))
    want = logits[:, S - 1].astype(np.float32)
    got = ld.astype(np.float32)
    rel = float(jnp.max(jnp.abs(want - got))
                / (jnp.max(jnp.abs(want)) + 1e-6))
    assert rel < 0.15, f"{arch}: decode/train divergence {rel}"
    # prefill's own last-token logits match the train path too
    np.testing.assert_allclose(np.asarray(lp, np.float32),
                               np.asarray(logits[:, S - 2], np.float32),
                               rtol=0.1, atol=0.15)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_exact_dims(arch):
    """The FULL config matches the assignment table (no allocation)."""
    cfg = get_config(arch)
    table = {
        "mixtral-8x22b": (56, 6144, 48, 8, 32768),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 49155),
        "internvl2-26b": (48, 6144, 48, 8, 92553),
        "gemma2-2b": (26, 2304, 8, 4, 256000),
        "minicpm-2b": (40, 2304, 36, 36, 122753),
        "command-r-plus-104b": (64, 12288, 96, 8, 256000),
        "nemotron-4-15b": (32, 6144, 48, 8, 256000),
        "whisper-large-v3": (32, 1280, 20, 20, 51866),
        "mamba2-1.3b": (48, 2048, 0, 0, 50280),
        "zamba2-2.7b": (54, 2560, 32, 32, 32000),
    }
    L, d, H, KV, V = table[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.vocab) == (L, d, H, KV, V)


def test_moe_dims():
    m = get_config("mixtral-8x22b").moe
    assert (m.n_experts, m.top_k, m.d_ff) == (8, 2, 16384)
    g = get_config("granite-moe-3b-a800m").moe
    assert (g.n_experts, g.top_k, g.d_ff) == (40, 8, 512)


def test_ssm_dims():
    s = get_config("mamba2-1.3b").ssm
    assert s.d_state == 128
    z = get_config("zamba2-2.7b").ssm
    assert z.d_state == 64
