"""Workload distribution: binary search + adaptive binary search
(paper Sec. 3.2.2 / 3.3.1) — unit + property + convergence."""
import math

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (AdaptiveBinarySearch, Distribution,
                        WorkloadDistributionGenerator, balance_until_stable,
                        run_binary_search)


def make_measure(speed_a: float, speed_b: float):
    """Times for a split: t_a = share_a/speed_a, t_b = share_b/speed_b."""
    def measure(d: Distribution):
        ta = d.a / speed_a if speed_a > 0 else math.inf
        tb = d.b / speed_b if speed_b > 0 else math.inf
        return ta, tb
    return measure


class TestGenerator:
    def test_transferable_halves(self):
        """Paper: transferableSize(n, size) = size / 2^n."""
        g = WorkloadDistributionGenerator()
        for n in range(8):
            assert g.transferable_size() == pytest.approx(0.5 ** n)
            g.next()
            g.feedback(1.0, 2.0)

    def test_binds_to_winner(self):
        """Paper: the winner's half of the transferable partition binds;
        the other half becomes the next transferable partition."""
        g = WorkloadDistributionGenerator()
        g.next()
        g.feedback(1.0, 2.0)        # a faster
        assert g.bound_a == pytest.approx(0.5)
        assert g.bound_b == 0.0
        assert g.transferable == pytest.approx(0.5)

    def test_feedback_requires_next(self):
        g = WorkloadDistributionGenerator()
        with pytest.raises(RuntimeError):
            g.feedback(1.0, 2.0)

    @settings(max_examples=40, deadline=None)
    @given(sa=st.floats(0.1, 10), sb=st.floats(0.1, 10))
    def test_converges_to_speed_ratio(self, sa, sb):
        """The optimum evens completion times: share_a* = sa/(sa+sb)."""
        dist, iters = run_binary_search(make_measure(sa, sb),
                                        precision=1e-4, max_iters=40)
        assert dist.a == pytest.approx(sa / (sa + sb), abs=2e-3)


class TestAdaptiveBinarySearch:
    def test_doubling_after_shifts(self):
        """>2 shifts in one direction double the transferable size."""
        s = AdaptiveBinarySearch(Distribution(a=0.2, b=0.8), step=0.02)
        sizes = []
        for _ in range(6):
            s.next()
            s.feedback(1.0, 5.0)        # a keeps winning -> shift right
            sizes.append(s.transferable)
        assert sizes[3] > sizes[1]      # doubling kicked in
        assert s.center.a > 0.2         # moved towards a

    def test_halving_on_alternation(self):
        s = AdaptiveBinarySearch(Distribution(a=0.5, b=0.5), step=0.08)
        s.next(); s.feedback(1.0, 2.0)
        t0 = s.transferable
        s.next(); s.feedback(2.0, 1.0)  # winner flips -> halve
        assert s.transferable == pytest.approx(t0 / 2)

    @settings(max_examples=30, deadline=None)
    @given(sa=st.floats(0.2, 5), sb=st.floats(0.2, 5),
           start=st.floats(0.05, 0.95))
    def test_rebalances_from_any_start(self, sa, sb, start):
        d, ops = balance_until_stable(
            make_measure(sa, sb), Distribution(a=start, b=1 - start),
            precision=1e-3, max_iters=200)
        assert d.a == pytest.approx(sa / (sa + sb), abs=0.05)

    def test_load_fluctuation_recovery(self):
        """Fig. 11: CPU slows down mid-run; the search follows."""
        speed_b = [1.0]
        def measure(d):
            return d.a / 4.0, d.b / speed_b[0]
        d, _ = balance_until_stable(measure, Distribution(a=0.8, b=0.2),
                                    precision=1e-3)
        assert d.a == pytest.approx(0.8, abs=0.05)
        speed_b[0] = 0.25               # external load: 4x slower CPU
        d2, _ = balance_until_stable(measure, d, precision=1e-3)
        assert d2.a == pytest.approx(4 / 4.25, abs=0.05)


class TestDistribution:
    def test_per_device_static_split(self):
        d = Distribution(a=0.8, b=0.2)
        shares = d.per_device([3.0, 1.0], [1.0])
        assert shares[0] == pytest.approx(0.6)
        assert shares[1] == pytest.approx(0.2)
        assert shares[2] == pytest.approx(0.2)
        assert sum(shares) == pytest.approx(1.0)

    def test_validation(self):
        with pytest.raises(ValueError):
            Distribution(a=0.7, b=0.7)
