"""Locality pipeline: plan cache, persistent pools, zero-copy merge,
partitioned residency (ISSUE 7).

Covers the plan-cache correctness matrix (hit on recurrent runs,
invalidation on quarantine/reinstatement and on adjusted shares,
bit-identical outputs vs. the uncached path including a fault-injected
repartition run), the in-place merge and its legacy-equivalence, pool
persistence, residency handoff/fallback, and the satellite fixes
(`_per_slot_shares` zero-total fallback, user merge-fn precedence).
"""
import math

import numpy as np
import pytest

from repro.core import (AcceleratorPlatform, DeviceInfo, ExecutionSlot,
                        FaultInjector, FaultPolicy, HostPlatform,
                        KnowledgeBase, LoadBalancer, PlanCache,
                        PlatformConfig, Profile, Scheduler, Session,
                        ThreadedExecutor, Workload, build_plan, kernel,
                        scalar, vector)

POLICY = FaultPolicy(watchdog_multiple=1e6)   # no spurious watchdog on CI


def saxpy_tree():
    return kernel(lambda a, x, y: a * x + y, name="saxpy",
                  inputs=[scalar("a"), vector("x"), vector("y")],
                  outputs=[vector("z")])


def chain_trees():
    k2 = kernel(lambda a, z: z * a, name="scale",
                inputs=[scalar("a"), vector("z")], outputs=[vector("w")])
    k3 = kernel(lambda w, y: w + y, name="addy",
                inputs=[vector("w"), vector("y")], outputs=[vector("v")])
    return [saxpy_tree(), k2, k3]


def saxpy_arrays(n=256, a=2.0):
    return {"a": np.float32(a),
            "x": np.arange(n, dtype=np.float32),
            "y": np.ones(n, dtype=np.float32)}


def make_scheduler(executor, **kw):
    host = HostPlatform(DeviceInfo("cpu0", "cpu", compute_units=4),
                        topology={"L2": 2, "NO_FISSION": 1})
    accel = AcceleratorPlatform([DeviceInfo("gpu0", "gpu")], max_overlap=2)
    kw.setdefault("balancer", LoadBalancer(max_dev=0.0))
    kw.setdefault("kb", KnowledgeBase())
    return Scheduler(host=host, accel=accel, executor=executor, **kw)


def three_slot_part(sct, n=256, shares=(0.5, 0.25, 0.25)):
    plan = build_plan(sct, {"x": (n,), "y": (n,)})
    slots = [ExecutionSlot("gpu0/q0", "gpu"),
             ExecutionSlot("cpu0/f0", "cpu"),
             ExecutionSlot("cpu0/f1", "cpu")]
    return plan.partition(slots, list(shares))


def make_profile(sct, n=256, share=0.5):
    return Profile(sct_id=sct.unique_id(), workload=Workload((n,)),
                   share_a=share, config=PlatformConfig(),
                   best_time=math.inf)


# ---------------------------------------------------------------------------
# Plan cache
# ---------------------------------------------------------------------------

class TestPlanCache:
    def test_recurrent_run_hits_cache(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        sct, arrays = saxpy_tree(), saxpy_arrays()
        r1 = sched.run(sct, dict(arrays))
        r2 = sched.run(sct, dict(arrays))
        r3 = sched.run(sct, dict(arrays))
        assert not r1.stats.plan_cache_hit
        assert r2.stats.plan_cache_hit and r3.stats.plan_cache_hit
        assert sched.plan_cache.hits == 2
        assert sched.plan_cache.misses == 1

    def test_workload_change_misses(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        sct = saxpy_tree()
        sched.run(sct, saxpy_arrays(n=256))
        r = sched.run(sct, saxpy_arrays(n=128))
        assert not r.stats.plan_cache_hit

    def test_bit_identical_to_uncached_path(self):
        sct, arrays = saxpy_tree(), saxpy_arrays()
        legacy = make_scheduler(ThreadedExecutor(
            policy=POLICY, persistent_pool=False, inplace_merge=False),
            plan_cache=False)
        expected = np.copy(legacy.run(sct, dict(arrays)).outputs["z"])
        cached = make_scheduler(ThreadedExecutor(policy=POLICY))
        for _ in range(3):
            got = np.copy(np.asarray(cached.run(sct,
                                                dict(arrays)).outputs["z"]))
            np.testing.assert_array_equal(expected, got)

    def test_bit_identical_under_fault_repartition(self):
        sct, arrays = saxpy_tree(), saxpy_arrays()
        legacy = make_scheduler(ThreadedExecutor(
            policy=POLICY, persistent_pool=False, inplace_merge=False),
            plan_cache=False)
        expected = np.copy(legacy.run(sct, dict(arrays)).outputs["z"])
        inj = FaultInjector(crash_on_call={"gpu0": [2]})
        sched = make_scheduler(ThreadedExecutor(policy=POLICY, injector=inj))
        sched.run(sct, dict(arrays))                 # populate the cache
        r = sched.run(sct, dict(arrays))             # cache hit + crash
        assert r.stats.plan_cache_hit
        assert r.stats.retries == 1
        np.testing.assert_array_equal(
            expected, np.copy(np.asarray(r.outputs["z"])))

    def test_invalidated_on_quarantine_and_reinstatement(self):
        inj = FaultInjector(crash_on_call={"gpu0": [2, 3]})
        sched = make_scheduler(ThreadedExecutor(policy=POLICY, injector=inj))
        sched.health.quarantine_after = 1
        sched.health.probe_after = 1
        sct, arrays = saxpy_tree(), saxpy_arrays()
        sched.run(sct, dict(arrays))                 # clean, cache filled
        sched.run(sct, dict(arrays))                 # gpu0 crash -> quarantine
        before = sched.plan_cache.invalidations
        r = sched.run(sct, dict(arrays))             # health version moved
        assert sched.plan_cache.invalidations == before + 1
        assert not r.stats.plan_cache_hit            # new (CPU-only) slots
        # probation probe succeeds -> reinstatement bumps the version again
        before = sched.plan_cache.invalidations
        sched.run(sct, dict(arrays))                 # probe run (clean)
        sched.run(sct, dict(arrays))
        assert sched.plan_cache.invalidations >= before + 1

    def test_invalidated_on_adjusted_shares(self):
        # an unbalanced balancer forces the "adjusted" action on the
        # recurrent path, which must explicitly invalidate the cache
        sched = make_scheduler(ThreadedExecutor(policy=POLICY),
                               balancer=LoadBalancer(max_dev=1.5, weight=1.0))
        sct, arrays = saxpy_tree(), saxpy_arrays()
        sched.run(sct, dict(arrays))
        before = sched.plan_cache.invalidations
        r = sched.run(sct, dict(arrays))
        assert r.action == "adjusted"
        assert sched.plan_cache.invalidations == before + 1

    def test_disabled_cache_never_hits(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY),
                               plan_cache=False)
        sct, arrays = saxpy_tree(), saxpy_arrays()
        for _ in range(3):
            assert not sched.run(sct, dict(arrays)).stats.plan_cache_hit
        assert sched.plan_cache.hits == 0

    def test_capacity_bound(self):
        cache = PlanCache(capacity=2)
        sct = saxpy_tree()
        slots = [ExecutionSlot("cpu0/f0", "cpu")]
        for n in (64, 128, 256):
            cache.partition(sct, {"x": (n,), "y": (n,)}, slots, [1.0])
        assert len(cache._parts) <= 2


# ---------------------------------------------------------------------------
# Persistent pools
# ---------------------------------------------------------------------------

class TestPersistentPool:
    def test_pool_created_once_and_reused(self):
        ex = ThreadedExecutor(policy=POLICY)
        sct = saxpy_tree()
        part = three_slot_part(sct)
        prof = make_profile(sct)
        for _ in range(3):
            ex.execute(sct, part, saxpy_arrays(), prof)
        assert ex.pools_created == 1
        assert ex.pool_reuses == 2
        ex.close()

    def test_legacy_flag_restores_per_run_pools(self):
        ex = ThreadedExecutor(policy=POLICY, persistent_pool=False)
        sct = saxpy_tree()
        part = three_slot_part(sct)
        prof = make_profile(sct)
        for _ in range(2):
            ex.execute(sct, part, saxpy_arrays(), prof)
        assert ex.pools_created == 0            # legacy path never registers
        assert ex._pool is None

    def test_session_shutdown_closes_executor(self):
        ex = ThreadedExecutor(policy=POLICY)
        sched = make_scheduler(ex)
        with Session(sched) as s:
            s.run(saxpy_tree(), **saxpy_arrays()).get()
        assert ex._pool is None
        assert ex._buffers == {}


# ---------------------------------------------------------------------------
# In-place merge
# ---------------------------------------------------------------------------

class TestInPlaceMerge:
    def test_matches_legacy_concatenate_merge(self):
        sct = saxpy_tree()
        part = three_slot_part(sct)
        prof = make_profile(sct)
        arrays = saxpy_arrays()
        legacy = ThreadedExecutor(policy=POLICY, inplace_merge=False,
                                  persistent_pool=False)
        expected, _ = legacy.execute(sct, part, dict(arrays), prof)
        ex = ThreadedExecutor(policy=POLICY)
        got, _ = ex.execute(sct, part, dict(arrays), prof)
        np.testing.assert_array_equal(np.asarray(expected["z"]),
                                      np.asarray(got["z"]))
        ex.close()

    def test_zero_merge_bytes_once_shape_learned(self):
        ex = ThreadedExecutor(policy=POLICY)
        sct = saxpy_tree()
        part = three_slot_part(sct)
        prof = make_profile(sct)
        ex.execute(sct, part, saxpy_arrays(), prof)     # learns shape
        assert ex.last_merge_bytes > 0                  # packing copy
        ex.execute(sct, part, saxpy_arrays(), prof)     # direct writes
        assert ex.last_merge_bytes == 0
        assert ex.last_direct_bytes == 256 * 4
        ex.close()

    def test_outputs_reuse_buffer_across_runs(self):
        ex = ThreadedExecutor(policy=POLICY)
        sct = saxpy_tree()
        part = three_slot_part(sct)
        prof = make_profile(sct)
        o1, _ = ex.execute(sct, part, saxpy_arrays(a=2.0), prof)
        z1 = o1["z"]
        o2, _ = ex.execute(sct, part, saxpy_arrays(a=3.0), prof)
        assert o2["z"] is z1        # documented aliasing semantics
        ex.close()

    def test_user_merge_fn_precedence_over_partitionable(self):
        # satellite: a user-supplied merge fn wins even though "z" is a
        # partitionable output that would otherwise be concatenated
        merges = {"z": lambda parts: sum(np.sum(p) for p in parts)}
        ex = ThreadedExecutor(policy=POLICY, merges=merges)
        sct = saxpy_tree()
        part = three_slot_part(sct)
        arrays = saxpy_arrays()
        out, _ = ex.execute(sct, part, dict(arrays), make_profile(sct))
        expected = np.sum(2.0 * arrays["x"] + arrays["y"])
        assert np.isclose(float(out["z"]), float(expected))
        ex.close()

    def test_buffers_dropped_after_timeout(self):
        inj = FaultInjector(stall_on_call={"gpu0": [2]}, stall_seconds=2.0)
        ex = ThreadedExecutor(
            policy=FaultPolicy(watchdog_multiple=1.0, min_deadline=0.2,
                               default_deadline=0.2), injector=inj)
        sct = saxpy_tree()
        part = three_slot_part(sct)
        prof = make_profile(sct)
        ex.execute(sct, part, saxpy_arrays(), prof)
        assert ex._buffers                       # learned + retained
        ex.execute(sct, part, saxpy_arrays(), prof)   # stall -> timeout
        assert any(r.kind == "timeout" for r in ex.last_failures)
        assert ex._buffers == {}                 # hung thread can't corrupt
        ex.close()


# ---------------------------------------------------------------------------
# Partitioned residency
# ---------------------------------------------------------------------------

class TestResidency:
    def expected_v(self, arrays):
        return (2.0 * (2.0 * arrays["x"] + arrays["y"])) + arrays["y"]

    def test_chain_matches_sequential_merge(self):
        arrays = saxpy_arrays()
        legacy = make_scheduler(ThreadedExecutor(
            policy=POLICY, persistent_pool=False, inplace_merge=False),
            plan_cache=False)
        env = dict(arrays)
        for sct in chain_trees():
            env.update({k: np.copy(v) for k, v in
                        legacy.run(sct, env).outputs.items()})
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        runs = sched.run_chain(chain_trees(), dict(arrays))
        np.testing.assert_array_equal(
            env["v"], np.copy(np.asarray(runs[-1].outputs["v"])))

    def test_intermediate_steps_stay_resident(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        runs = sched.run_chain(chain_trees(), saxpy_arrays())
        assert [r.stats.resident for r in runs] == [True, True, False]
        assert all(r.stats.merge_bytes == 0 for r in runs[:-1])
        assert runs[0].outputs == {}             # merge skipped entirely

    def test_fault_falls_back_to_full_merge(self):
        arrays = saxpy_arrays()
        inj = FaultInjector(crash_on_call={"gpu0": [1]})
        sched = make_scheduler(ThreadedExecutor(policy=POLICY, injector=inj))
        runs = sched.run_chain(chain_trees(), dict(arrays))
        assert runs[0].stats.retries == 1
        assert not runs[0].stats.resident        # repartitioned -> merged
        np.testing.assert_array_equal(
            self.expected_v(arrays),
            np.copy(np.asarray(runs[-1].outputs["v"])))

    def test_incompatible_partitioning_materializes(self):
        ex = ThreadedExecutor(policy=POLICY)
        sct = saxpy_tree()
        prof = make_profile(sct)
        ex.execute(sct, three_slot_part(sct), saxpy_arrays(), prof,
                   keep_resident=True)
        res = ex.last_resident
        assert res is not None
        other = three_slot_part(sct, shares=(0.25, 0.5, 0.25))
        assert not res.compatible(other)
        merged = res.materialize()
        expected = 2.0 * saxpy_arrays()["x"] + saxpy_arrays()["y"]
        np.testing.assert_array_equal(expected, np.asarray(merged["z"]))
        ex.close()

    def test_simulator_has_no_residency(self):
        from repro.core import SimulatedExecutor
        assert SimulatedExecutor.supports_residency is False

    def test_session_run_chain(self):
        arrays = saxpy_arrays()
        with Session(make_scheduler(ThreadedExecutor(policy=POLICY))) as s:
            runs = s.run_chain(chain_trees(), **arrays).get()
        np.testing.assert_array_equal(
            self.expected_v(arrays),
            np.copy(np.asarray(runs[-1].outputs["v"])))


# ---------------------------------------------------------------------------
# Timing instrumentation
# ---------------------------------------------------------------------------

class TestTimingBreakdown:
    def test_breakdown_populated(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        r = sched.run(saxpy_tree(), saxpy_arrays())
        s = r.stats
        assert s.plan_seconds > 0
        assert s.compute_seconds > 0
        assert s.merge_seconds >= 0
        assert s.overhead_seconds == pytest.approx(
            s.plan_seconds + s.pool_seconds + s.dispatch_seconds
            + s.merge_seconds)

    def test_simulator_reports_timing(self):
        from repro.core import SimDevice, SimulatedExecutor
        ex = SimulatedExecutor([SimDevice("gpu0", "gpu", flops=1e12),
                                SimDevice("cpu0", "cpu", flops=1e11,
                                          cores=4)])
        sched = make_scheduler(ex)
        r = sched.run(saxpy_tree(), saxpy_arrays())
        assert r.stats.merge_bytes == 0
        assert r.stats.compute_seconds > 0


# ---------------------------------------------------------------------------
# Satellite: zero-total share fallback
# ---------------------------------------------------------------------------

class TestZeroShareFallback:
    def test_all_probing_with_zero_probe_share(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        sched.health.probe_share = 0.0
        sched.health.quarantine_after = 1
        sched.health.probe_after = 0
        # quarantine every device, then let them all probe at share 0
        sched.health.record_failure("gpu0")
        sched.health.record_failure("cpu0")
        prof = make_profile(saxpy_tree())
        slots = sched._slots(prof)
        shares = sched._per_slot_shares(prof, slots)   # no ZeroDivisionError
        assert shares == pytest.approx([1.0 / len(slots)] * len(slots))
        assert sum(shares) == pytest.approx(1.0)
