"""Simulator calibration + benchmark-harness integration tests."""
import pytest

from benchmarks.fission import OPTERON_TOPOLOGY, simulate_fission
from benchmarks.hybrid import tune_cell
from benchmarks.paper_suite import BENCHMARKS, cost_model_for, workload_for
from repro.core.simulator import (CACHE_BYTES, LOCALITY_FACTOR, SimDevice,
                                  SimulatedExecutor)


class TestSimulator:
    def test_deterministic(self):
        from benchmarks.fission import simulate_fission
        a = simulate_fission("saxpy", 10 ** 6)
        b = simulate_fission("saxpy", 10 ** 6)
        assert a["times"] == b["times"]

    def test_fission_beats_no_fission(self):
        """The paper's central CPU result, on the calibrated box."""
        r = simulate_fission("fft", 256)
        assert r["best_level"] != "NO_FISSION"
        assert r["speedup_vs_nofission"] > 1.3

    def test_locality_calibration_order(self):
        assert LOCALITY_FACTOR["L2"] > LOCALITY_FACTOR["L3"] \
            > LOCALITY_FACTOR["NO_FISSION"]


class TestHybridBench:
    def test_hybrid_beats_gpu_only_for_comm_bound(self):
        """Paper Fig 7: saxpy/segmentation gain ~2x from the CPU."""
        r = tune_cell("saxpy", 10 ** 7, n_gpus=1)
        assert r["speedup"] > 1.2
        assert 0.0 < r["gpu_share"] < 1.0

    def test_nbody_stays_gpu_only(self):
        """Paper: compute-bound NBody assigns (almost) all work to GPUs."""
        r = tune_cell("nbody", 32768, n_gpus=1)
        assert r["gpu_share"] > 0.9

    def test_cpu_share_shrinks_with_more_gpus(self):
        r1 = tune_cell("segmentation", 512, n_gpus=1)
        r2 = tune_cell("segmentation", 512, n_gpus=2)
        assert (1 - r2["gpu_share"]) <= (1 - r1["gpu_share"]) + 0.05
