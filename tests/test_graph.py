"""JobGraph IR + concurrent submission pipeline (ISSUE 9).

Covers the IR itself (append-only acyclic construction, chain
degeneracy, structure queries), graph execution on the threaded
executor (fan-out / diamond bit-identity vs. sequential runs, per-node
fault containment and retry, residency along chain edges), the
virtual-time path on the SimulatedExecutor (deterministic overlap of
independent nodes, serialisation of chains), Session.submit/gather
with backpressure, and the satellite fixes (deadline-capped retry
backoff, ScheduledRun.detach, shutdown-path idempotency).
"""
import math
import threading
import time

import numpy as np
import pytest

from repro.core import (AcceleratorPlatform, DeviceInfo, ExecutionError,
                        FaultInjector, FaultPolicy, GraphError, GraphHandle,
                        HostPlatform, JobGraph, KnowledgeBase, LoadBalancer,
                        PlatformConfig, Profile, Scheduler, Session,
                        ThreadedExecutor, Workload, kernel, scalar, vector)
from repro.core.simulator import CostModel, SimDevice, SimulatedExecutor

POLICY = FaultPolicy(watchdog_multiple=1e6)   # no spurious watchdog on CI


def saxpy_tree():
    return kernel(lambda a, x, y: a * x + y, name="saxpy",
                  inputs=[scalar("a"), vector("x"), vector("y")],
                  outputs=[vector("z")])


def mul_tree():
    return kernel(lambda x, y: x * y, name="mul",
                  inputs=[vector("x"), vector("y")], outputs=[vector("w")])


def sub_tree():
    return kernel(lambda x, y: x - y, name="sub",
                  inputs=[vector("x"), vector("y")], outputs=[vector("v")])


def chain_trees():
    k2 = kernel(lambda a, z: z * a, name="scale",
                inputs=[scalar("a"), vector("z")], outputs=[vector("w")])
    k3 = kernel(lambda w, y: w + y, name="addy",
                inputs=[vector("w"), vector("y")], outputs=[vector("v")])
    return [saxpy_tree(), k2, k3]


def bad_tree():
    def boom(x, y):
        raise RuntimeError("deliberate kernel failure")
    return kernel(boom, name="boom",
                  inputs=[vector("x"), vector("y")], outputs=[vector("b")])


def saxpy_arrays(n=256, a=2.0):
    return {"a": np.float32(a),
            "x": np.arange(n, dtype=np.float32),
            "y": np.ones(n, dtype=np.float32)}


def make_scheduler(executor, **kw):
    host = HostPlatform(DeviceInfo("cpu0", "cpu", compute_units=4),
                        topology={"L2": 2, "NO_FISSION": 1})
    accel = AcceleratorPlatform([DeviceInfo("gpu0", "gpu")], max_overlap=2)
    kw.setdefault("balancer", LoadBalancer(max_dev=0.0))
    kw.setdefault("kb", KnowledgeBase())
    return Scheduler(host=host, accel=accel, executor=executor, **kw)


def sim_devices():
    return [SimDevice("gpu0", "gpu", flops=1e12),
            SimDevice("cpu0", "cpu", flops=1e11, cores=4)]


def make_sim(**kw):
    """Virtual executor whose compute dwarfs the per-slot dispatch
    overhead, so node spans reflect the pinned workload shares."""
    kw.setdefault("cost", CostModel(flops_per_unit=1e6, bytes_per_unit=0.0))
    kw.setdefault("compute_outputs", True)   # chains need real dataflow
    return SimulatedExecutor(sim_devices(), noise=0.0, **kw)


def pin_share(sched, sct, n, share):
    """Pre-store a KB profile so derivation pins the workload share."""
    sched.kb.store(Profile(sct_id=sct.unique_id(), workload=Workload((n,)),
                           share_a=share, config=PlatformConfig(),
                           best_time=math.inf))


# ---------------------------------------------------------------------------
# The IR
# ---------------------------------------------------------------------------

class TestJobGraphIR:
    def test_append_only_construction(self):
        g = JobGraph()
        a = g.add(saxpy_tree(), name="a")
        b = g.add(mul_tree(), name="b", after=a)
        assert g.deps(b) == ("a",)
        assert g.successors(a) == ["b"]
        assert g.roots() == ["a"] and g.sinks() == ["b"]
        assert g.topo_order() == ["a", "b"]
        assert len(g) == 2 and "a" in g and list(g) == ["a", "b"]

    def test_auto_names_are_unique(self):
        g = JobGraph()
        n1 = g.add(saxpy_tree())
        n2 = g.add(saxpy_tree())
        assert n1 != n2 and n1 in g and n2 in g

    def test_duplicate_name_rejected(self):
        g = JobGraph()
        g.add(saxpy_tree(), name="a")
        with pytest.raises(GraphError, match="duplicate"):
            g.add(saxpy_tree(), name="a")

    def test_unknown_dependency_rejected(self):
        g = JobGraph()
        with pytest.raises(GraphError, match="unknown dependency"):
            g.add(saxpy_tree(), name="a", after="ghost")

    def test_forward_dependency_unrepresentable(self):
        # cycles cannot be expressed: after may only name earlier nodes
        g = JobGraph()
        g.add(saxpy_tree(), name="a")
        with pytest.raises(GraphError):
            g.add(mul_tree(), name="b", after=("a", "c"))

    def test_empty_graph_invalid(self):
        with pytest.raises(GraphError, match="empty"):
            JobGraph().validate()

    def test_from_chain_is_degenerate_case(self):
        g = JobGraph.from_chain(chain_trees())
        names = g.topo_order()
        assert len(names) == 3
        assert g.roots() == [names[0]] and g.sinks() == [names[2]]
        assert g.is_chain_edge(names[0], names[1])
        assert g.is_chain_edge(names[1], names[2])

    def test_fan_out_edges_are_not_chain_edges(self):
        g = JobGraph()
        a = g.add(saxpy_tree(), name="a")
        g.add(mul_tree(), name="b", after=a)
        g.add(sub_tree(), name="c", after=a)
        assert not g.is_chain_edge("a", "b")
        assert not g.is_chain_edge("a", "c")
        assert g.out_degree("a") == 2 and g.in_degree("b") == 1

    def test_ancestors_diamond(self):
        g = JobGraph()
        g.add(saxpy_tree(), name="a")
        g.add(mul_tree(), name="b", after="a")
        g.add(sub_tree(), name="c", after="a")
        g.add(mul_tree(), name="d", after=("b", "c"))
        assert g.ancestors("d") == ["a", "b", "c"]
        assert g.ancestors("a") == []


# ---------------------------------------------------------------------------
# Threaded graph execution
# ---------------------------------------------------------------------------

class TestThreadedGraphs:
    def test_single_node_graph_equals_run(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        g = JobGraph()
        g.add(saxpy_tree(), name="only")
        handle = sched.submit(g, saxpy_arrays())
        res = handle.result(timeout=60)
        x = saxpy_arrays()["x"]
        np.testing.assert_array_equal(res.outputs["z"], 2.0 * x + 1.0)
        assert res.order == ["only"]
        assert handle.status() == {"only": "done"}
        sched.close()

    def test_fan_out_bit_identical_to_sequential(self):
        arrays = saxpy_arrays()
        scts = [saxpy_tree(), mul_tree(), sub_tree()]

        seq = make_scheduler(ThreadedExecutor(policy=POLICY))
        expected = {}
        for sct in scts:
            expected.update(seq.run(sct, dict(arrays)).outputs)
        seq.close()

        par = make_scheduler(ThreadedExecutor(policy=POLICY))
        g = JobGraph()
        for sct in scts:
            g.add(sct)
        res = par.submit(g, arrays).result(timeout=60)
        assert set(res.outputs) == {"z", "w", "v"}
        for name in expected:
            np.testing.assert_array_equal(res.outputs[name], expected[name])
        par.close()

    def test_diamond_fan_in_bit_identical(self):
        arrays = saxpy_arrays()
        a_sct = saxpy_tree()
        b_sct = kernel(lambda z, x: z * x, name="zb",
                       inputs=[vector("z"), vector("x")],
                       outputs=[vector("w")])
        c_sct = kernel(lambda z, y: z + y, name="zc",
                       inputs=[vector("z"), vector("y")],
                       outputs=[vector("v")])
        d_sct = kernel(lambda w, v: w - v, name="zd",
                       inputs=[vector("w"), vector("v")],
                       outputs=[vector("u")])

        seq = make_scheduler(ThreadedExecutor(policy=POLICY))
        env = dict(arrays)
        for sct in (a_sct, b_sct, c_sct, d_sct):
            env.update(seq.run(sct, dict(env)).outputs)
        seq.close()

        par = make_scheduler(ThreadedExecutor(policy=POLICY))
        g = JobGraph()
        g.add(a_sct, name="a")
        g.add(b_sct, name="b", after="a")
        g.add(c_sct, name="c", after="a")
        g.add(d_sct, name="d", after=("b", "c"))
        res = par.submit(g, arrays).result(timeout=60)
        np.testing.assert_array_equal(res.outputs["u"], env["u"])
        assert res.runs["b"] is not None and res.runs["c"] is not None
        par.close()

    def test_parallel_branches_never_see_each_other(self):
        # b and c both produce "w"; d depends only on b, so it must see
        # b's w even when c finishes later (ancestor layering, not
        # completion order)
        arrays = saxpy_arrays()
        b_sct = kernel(lambda x: x * 2.0, name="wb",
                       inputs=[vector("x")], outputs=[vector("w")])
        c_sct = kernel(lambda x: x * 3.0, name="wc",
                       inputs=[vector("x")], outputs=[vector("w")])
        d_sct = kernel(lambda w: w + 1.0, name="wd",
                       inputs=[vector("w")], outputs=[vector("u")])
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        g = JobGraph()
        g.add(b_sct, name="b")
        g.add(c_sct, name="c")
        g.add(d_sct, name="d", after="b")
        res = sched.submit(g, arrays).result(timeout=60)
        np.testing.assert_array_equal(
            res.runs["d"].outputs["u"], arrays["x"] * 2.0 + 1.0)
        sched.close()

    def test_node_failure_contained_siblings_complete(self):
        arrays = saxpy_arrays()
        sched = make_scheduler(ThreadedExecutor(
            policy=FaultPolicy(max_attempts=1, watchdog_multiple=1e6)))
        g = JobGraph()
        g.add(bad_tree(), name="bad")
        g.add(saxpy_tree(), name="good")
        g.add(mul_tree(), name="child", after="bad")
        handle = sched.submit(g, arrays)
        with pytest.raises(ExecutionError, match="graph node 'bad'"):
            handle.result(timeout=60)
        status = handle.status()
        assert status["bad"] == "failed"
        assert status["child"] == "skipped"
        assert status["good"] == "done"
        # the independent branch's run stays accessible
        np.testing.assert_array_equal(
            handle.runs["good"].outputs["z"], 2.0 * arrays["x"] + 1.0)
        assert handle.error is not None and handle.error.records
        sched.close()

    def test_failed_node_error_carries_device_identity(self):
        sct = saxpy_tree()
        inj = FaultInjector(crash_prob=1.0)
        sched = make_scheduler(ThreadedExecutor(injector=inj, policy=POLICY))
        g = JobGraph()
        g.add(sct, name="n")
        handle = sched.submit(g, saxpy_arrays())
        with pytest.raises(ExecutionError) as ei:
            handle.result(timeout=60)
        assert "gpu0" in str(ei.value) or "cpu0" in str(ei.value)
        assert ei.value.records
        sched.close()

    def test_per_node_retry_recovers(self):
        sct = saxpy_tree()
        inj = FaultInjector(crash_on_call={"gpu0": [1]})
        sched = make_scheduler(ThreadedExecutor(
            injector=inj,
            policy=FaultPolicy(max_attempts=1, watchdog_multiple=1e6)))
        g = JobGraph()
        g.add(sct, name="n")
        handle = sched.submit(g, saxpy_arrays(), retries=2,
                              retry_backoff=0.01)
        res = handle.result(timeout=60)
        x = saxpy_arrays()["x"]
        np.testing.assert_array_equal(res.outputs["z"], 2.0 * x + 1.0)
        sched.close()

    def test_residency_flows_along_graph_chain_edges(self):
        arrays = saxpy_arrays()
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        g = JobGraph.from_chain(chain_trees())
        res = sched.submit(g, arrays).result(timeout=60)
        np.testing.assert_allclose(
            res.outputs["v"], (2.0 * arrays["x"] + 1.0) * 2.0 + 1.0,
            rtol=1e-6)
        assert sched.counters()["scheduler.resident_handoffs"] >= 1
        sched.close()

    def test_residency_false_forces_merge(self):
        arrays = saxpy_arrays()
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        g = JobGraph()
        a = g.add(chain_trees()[0], name="a", residency=False)
        g.add(chain_trees()[1], name="b", after=a)
        res = sched.submit(g, arrays).result(timeout=60)
        assert sched.counters()["scheduler.resident_handoffs"] == 0
        # merged intermediate is visible on the sink path
        np.testing.assert_allclose(
            res.runs["b"].outputs["w"], (2.0 * arrays["x"] + 1.0) * 2.0,
            rtol=1e-6)
        sched.close()

    def test_graph_counters_and_events(self):
        from repro.core import Telemetry
        tel = Telemetry()
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        sched.attach_telemetry(tel)
        g = JobGraph()
        g.add(saxpy_tree())
        sched.submit(g, saxpy_arrays()).result(timeout=60)
        assert sched.counters()["scheduler.graphs"] == 1
        kinds = {e.kind for e in tel.events.records()}
        assert {"graph.submitted", "graph.admitted",
                "graph.done"} <= kinds
        sched.close()


# ---------------------------------------------------------------------------
# Virtual-time graph execution (SimulatedExecutor)
# ---------------------------------------------------------------------------

class TestVirtualGraphs:
    def test_fan_out_overlaps_in_virtual_time(self):
        n = 4096
        scts = [saxpy_tree(), mul_tree(), sub_tree()]
        sched = make_scheduler(make_sim())
        # cpu-heavy share: each node's short gpu leg clears the gpu queue
        # quickly while its long cpu leg is still running, so all three
        # nodes end up simultaneously in flight
        for sct in scts:
            pin_share(sched, sct, n, 0.1)
        g = JobGraph()
        names = [g.add(sct) for sct in scts]
        handle = sched.submit(g, saxpy_arrays(n))
        assert handle.done()            # virtual graphs settle inline
        spans = handle.spans()
        assert len(spans) == 3
        # all three nodes run at the instant the last one starts
        last_start = max(s for s, _ in spans.values())
        first_end = min(e for _, e in spans.values())
        assert last_start < first_end, spans
        assert all(handle.status()[nm] == "done" for nm in names)

    def test_chain_serialises_in_virtual_time(self):
        n = 4096
        sched = make_scheduler(make_sim())
        for sct in chain_trees():
            pin_share(sched, sct, n, 0.5)
        g = JobGraph.from_chain(chain_trees())
        handle = sched.submit(g, saxpy_arrays(n))
        spans = [handle.spans()[nm] for nm in g.topo_order()]
        for (s0, e0), (s1, e1) in zip(spans, spans[1:]):
            assert s1 >= e0 - 1e-6      # dataflow: no overlap along a chain
            assert e1 > e0

    def test_virtual_queue_contention_is_shared_across_requests(self):
        n = 4096
        sched = make_scheduler(make_sim())
        pin_share(sched, saxpy_tree(), n, 0.5)
        g1 = JobGraph()
        g1.add(saxpy_tree(), name="n1")
        g2 = JobGraph()
        g2.add(saxpy_tree(), name="n2")
        h1 = sched.submit(g1, saxpy_arrays(n))
        h2 = sched.submit(g2, saxpy_arrays(n))
        (s1, e1) = h1.spans()["n1"]
        (s2, e2) = h2.spans()["n2"]
        # second request queues behind the first on busy device queues
        assert e2 > e1 and s2 >= s1

    def test_virtual_failure_skips_descendants(self):
        n = 4096
        inj = FaultInjector(crash_prob=1.0)
        sched = make_scheduler(make_sim(
            injector=inj, policy=FaultPolicy(max_attempts=2)))
        g = JobGraph()
        g.add(saxpy_tree(), name="a")
        g.add(mul_tree(), name="b", after="a")
        handle = sched.submit(g, saxpy_arrays(n))
        with pytest.raises(ExecutionError, match="graph node 'a'"):
            handle.result(timeout=1)
        assert handle.status() == {"a": "failed", "b": "skipped"}


# ---------------------------------------------------------------------------
# Session.submit / gather / backpressure
# ---------------------------------------------------------------------------

class TestSessionGraphs:
    def test_submit_and_gather(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        arrays = saxpy_arrays()
        with Session(sched) as sess:
            g1 = JobGraph()
            g1.add(saxpy_tree(), name="s")
            g2 = JobGraph()
            g2.add(mul_tree(), name="m")
            h1 = sess.submit(g1, **arrays)
            h2 = sess.submit(g2, **arrays)
            r1, r2 = sess.gather(h1, h2, timeout=60)
        np.testing.assert_array_equal(r1.outputs["z"],
                                      2.0 * arrays["x"] + 1.0)
        np.testing.assert_array_equal(r2.outputs["w"],
                                      arrays["x"] * arrays["y"])

    def test_run_and_run_chain_are_graph_wrappers(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        arrays = saxpy_arrays()
        with Session(sched) as sess:
            out = sess.run(saxpy_tree(), **arrays).get(timeout=60)
            runs = sess.run_chain(chain_trees(), **arrays).get(timeout=60)
        np.testing.assert_array_equal(out.outputs["z"],
                                      2.0 * arrays["x"] + 1.0)
        assert len(runs) == 3
        np.testing.assert_allclose(
            runs[-1].outputs["v"], (2.0 * arrays["x"] + 1.0) * 2.0 + 1.0,
            rtol=1e-6)

    def test_max_inflight_validation(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        with pytest.raises(ValueError):
            Session(sched, max_inflight=0)
        sched.close()

    def test_backpressure_blocks_beyond_max_inflight(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        gate = threading.Event()

        def slow_fn(x):
            gate.wait(10)
            return x
        slow = kernel(slow_fn, name="slow", inputs=[vector("x")],
                      outputs=[vector("o")])
        sess = Session(sched, max_inflight=1)
        g1 = JobGraph()
        g1.add(slow, name="s")
        h1 = sess.submit(g1, x=np.ones(8, dtype=np.float32))

        second = {}

        def try_second():
            g2 = JobGraph()
            g2.add(saxpy_tree(), name="n")
            second["handle"] = sess.submit(g2, **saxpy_arrays())

        t = threading.Thread(target=try_second)
        t.start()
        t.join(0.3)
        assert t.is_alive()             # blocked: slot still held by g1
        gate.set()
        t.join(30)
        assert not t.is_alive()
        assert h1.result(timeout=30) is not None
        assert second["handle"].result(timeout=30) is not None
        sess.shutdown()

    def test_many_submissions_with_tight_inflight(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        arrays = saxpy_arrays()
        with Session(sched, max_inflight=2) as sess:
            handles = []
            for _ in range(6):
                g = JobGraph()
                g.add(saxpy_tree(), name="n")
                handles.append(sess.submit(g, **arrays))
            results = sess.gather(*handles, timeout=60)
        for r in results:
            np.testing.assert_array_equal(r.outputs["z"],
                                          2.0 * arrays["x"] + 1.0)

    def test_submit_after_shutdown_raises(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        sess = Session(sched)
        sess.shutdown()
        g = JobGraph()
        g.add(saxpy_tree(), name="n")
        with pytest.raises(RuntimeError, match="shut down"):
            sess.submit(g, **saxpy_arrays())


# ---------------------------------------------------------------------------
# Satellites: deadline-capped backoff, detach, shutdown paths
# ---------------------------------------------------------------------------

class TestDeadlineCappedBackoff:
    def test_backoff_never_sleeps_past_deadline(self):
        sct = saxpy_tree()
        inj = FaultInjector(crash_prob=1.0)
        sched = make_scheduler(ThreadedExecutor(
            injector=inj,
            policy=FaultPolicy(max_attempts=1, watchdog_multiple=1e6,
                               default_deadline=None)))
        with Session(sched) as sess:
            t0 = time.monotonic()
            fut = sess.run(sct, deadline=0.3, retries=8,
                           retry_backoff=10.0, **saxpy_arrays())
            with pytest.raises(ExecutionError,
                               match="deadline|did not complete"):
                fut.get()
            elapsed = time.monotonic() - t0
        # without the cap the first pause alone would sleep 10s
        assert elapsed < 3.0, elapsed

    def test_deadline_exhaustion_message_counts_attempts(self):
        sct = saxpy_tree()
        inj = FaultInjector(crash_prob=1.0)
        sched = make_scheduler(ThreadedExecutor(
            injector=inj,
            policy=FaultPolicy(max_attempts=1, watchdog_multiple=1e6,
                               default_deadline=None)))
        g = JobGraph()
        g.add(sct, name="n")
        handle = sched.submit(g, saxpy_arrays(), deadline=0.2, retries=50,
                              retry_backoff=0.05)
        with pytest.raises(ExecutionError,
                           match="request deadline .* exceeded"):
            handle.result(timeout=30)
        sched.close()


class TestDetach:
    def test_detach_survives_buffer_reuse(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY,
                                                reuse_buffers=True))
        sct = saxpy_tree()
        r1 = sched.run(sct, saxpy_arrays(a=2.0)).detach()
        z1 = np.copy(r1.outputs["z"])
        sched.run(sct, saxpy_arrays(a=5.0))     # reuses the merge buffer
        np.testing.assert_array_equal(r1.outputs["z"], z1)
        sched.close()

    def test_detach_returns_self_and_copies(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY,
                                                reuse_buffers=True))
        sct = saxpy_tree()
        r = sched.run(sct, saxpy_arrays())
        before = r.outputs["z"]
        assert r.detach() is r
        assert r.outputs["z"] is not before
        np.testing.assert_array_equal(r.outputs["z"], before)
        sched.close()


class TestShutdownPaths:
    def test_session_shutdown_idempotent(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        sess = Session(sched)
        sess.run(saxpy_tree(), **saxpy_arrays()).get(timeout=60)
        sess.shutdown()
        sess.shutdown()                 # second call is a no-op
        with sess:                      # CM exit after explicit shutdown
            pass

    def test_executor_double_close(self):
        ex = ThreadedExecutor(policy=POLICY)
        sched = make_scheduler(ex)
        sched.run(saxpy_tree(), saxpy_arrays())
        ex.close()
        ex.close()                      # idempotent
        assert ex._queues == {} and ex._buffers == {}

    def test_shutdown_with_inflight_requests_drains(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        sess = Session(sched)
        handles = []
        for _ in range(4):
            g = JobGraph()
            g.add(saxpy_tree(), name="n")
            handles.append(sess.submit(g, **saxpy_arrays(n=2048)))
        sess.shutdown()                 # drains, then closes
        for h in handles:
            assert h.done()
            assert h.result(timeout=1).outputs["z"].shape == (2048,)

    def test_scheduler_close_idempotent_and_rejects_submissions(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        sched.close()
        sched.close()
        g = JobGraph()
        g.add(saxpy_tree(), name="n")
        with pytest.raises(RuntimeError, match="closed"):
            sched.submit(g, saxpy_arrays())
