"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes/dtypes
(interpret=True executes the kernel body with real BlockSpec indexing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def k(i):
    return jax.random.fold_in(KEY, i)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_SHAPES = [
    # B, H, KV, Sq, Sk, hd
    (1, 2, 2, 64, 64, 16),       # MHA, block-aligned
    (2, 4, 2, 75, 75, 32),       # GQA 2:1, ragged seq
    (1, 8, 1, 33, 130, 8),       # MQA, Sq != Sk
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("variant", ["causal", "full", "window",
                                     "softcap", "window+cap"])
def test_flash_attention_variants(shape, variant):
    B, H, KV, Sq, Sk, hd = shape
    q = jax.random.normal(k(1), (B, H, Sq, hd), jnp.float32)
    kk = jax.random.normal(k(2), (B, KV, Sk, hd), jnp.float32)
    v = jax.random.normal(k(3), (B, KV, Sk, hd), jnp.float32)
    kw = dict(causal=True)
    if variant == "full":
        kw = dict(causal=False)
    elif variant == "window":
        kw = dict(causal=True, window=16)
    elif variant == "softcap":
        kw = dict(causal=True, logit_cap=20.0)
    elif variant == "window+cap":
        kw = dict(causal=True, window=24, logit_cap=30.0)
    got = ops.flash_attention(q, kk, v, block_q=32, block_k=32, **kw)
    want = ref.attention_ref(q, kk, v, **kw)
    assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    B, H, KV, S, hd = 1, 4, 4, 64, 32
    q = jax.random.normal(k(4), (B, H, S, hd), dtype)
    kk = jax.random.normal(k(5), (B, KV, S, hd), dtype)
    v = jax.random.normal(k(6), (B, KV, S, hd), dtype)
    got = ops.flash_attention(q, kk, v, block_q=32, block_k=32)
    want = ref.attention_ref(q, kk, v)
    tol = 3e-4 if dtype == jnp.float32 else 3e-2
    assert_allclose(got.astype(np.float32), want.astype(np.float32),
                    rtol=tol, atol=tol)
    assert got.dtype == dtype


def test_flash_attention_kv_len_mask():
    B, H, KV, S, hd = 1, 2, 2, 64, 16
    q = jax.random.normal(k(7), (B, H, S, hd), jnp.float32)
    kk = jax.random.normal(k(8), (B, KV, S, hd), jnp.float32)
    v = jax.random.normal(k(9), (B, KV, S, hd), jnp.float32)
    got = ops.flash_attention(q, kk, v, kv_len=40, causal=False,
                              block_q=32, block_k=32)
    want = ref.attention_ref(q[:, :, :, :], kk[:, :, :40], v[:, :, :40],
                             causal=False)
    assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_flash_matches_model_oracle():
    """The models' blockwise_attention is itself validated vs the kernel."""
    from repro.models.attention import blockwise_attention
    B, H, KV, S, hd = 2, 4, 2, 96, 16
    q = jax.random.normal(k(10), (B, S, H, hd), jnp.float32)
    kk = jax.random.normal(k(11), (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(k(12), (B, S, KV, hd), jnp.float32)
    want = blockwise_attention(q, kk, v, causal=True, q_block=32,
                               k_block=32)
    got = ops.flash_attention_bshd(q, kk, v, causal=True, block_q=32,
                                   block_k=32)
    assert_allclose(got, want, rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_SHAPES = [
    # B, S, nh, hd, ds, chunk
    (1, 32, 2, 8, 8, 8),
    (2, 48, 4, 8, 16, 16),
    (1, 64, 4, 16, 16, 64),       # single chunk
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
def test_ssd_scan_shapes(shape):
    B, S, nh, hd, ds, chunk = shape
    x = jax.random.normal(k(20), (B, S, nh * hd), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(k(21), (B, S, nh)))
    Bm = jax.random.normal(k(22), (B, S, ds)) * 0.5
    Cm = jax.random.normal(k(23), (B, S, ds)) * 0.5
    A = -jnp.exp(jax.random.normal(k(24), (nh,)) * 0.3)
    y1, h1 = ops.ssd_scan(x, dt, Bm, Cm, A, chunk=chunk)
    y2, h2 = ref.ssd_scan_ref(x, dt, Bm, Cm, A, chunk=chunk)
    assert_allclose(y1, y2, rtol=3e-4, atol=3e-4)
    assert_allclose(h1, h2, rtol=3e-4, atol=3e-4)


def test_ssd_scan_state_chaining():
    """h0 continuation: two half-sequences == one full sequence."""
    B, S, nh, hd, ds, chunk = 1, 32, 2, 8, 8, 8
    x = jax.random.normal(k(25), (B, S, nh * hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(k(26), (B, S, nh)))
    Bm = jax.random.normal(k(27), (B, S, ds)) * 0.5
    Cm = jax.random.normal(k(28), (B, S, ds)) * 0.5
    A = -jnp.exp(jax.random.normal(k(29), (nh,)) * 0.3)
    y_full, h_full = ops.ssd_scan(x, dt, Bm, Cm, A, chunk=chunk)
    y1, h1 = ops.ssd_scan(x[:, :16], dt[:, :16], Bm[:, :16], Cm[:, :16],
                          A, chunk=chunk)
    y2, h2 = ops.ssd_scan(x[:, 16:], dt[:, 16:], Bm[:, 16:], Cm[:, 16:],
                          A, chunk=chunk, h0=h1)
    assert_allclose(jnp.concatenate([y1, y2], 1), y_full, rtol=3e-4,
                    atol=3e-4)
    assert_allclose(h2, h_full, rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(2, 16, 32, 24), (3, 37, 65, 41),
                                   (1, 128, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul(shape, dtype):
    E, C, d, f = shape
    x = jax.random.normal(k(30), (E, C, d), dtype)
    w = jax.random.normal(k(31), (E, d, f), dtype)
    got = ops.grouped_matmul(x, w, block_c=16, block_f=16, block_d=32)
    want = ref.grouped_matmul_ref(x, w)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    assert_allclose(got.astype(np.float32), want.astype(np.float32),
                    rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# paper benchmark kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [64, 1000, 4096])
def test_saxpy(n):
    x = jax.random.normal(k(40), (n,))
    y = jax.random.normal(k(41), (n,))
    assert_allclose(ops.saxpy(2.5, x, y, block=256),
                    ref.saxpy_ref(2.5, x, y), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("hw", [(32, 32), (50, 36), (64, 128)])
def test_filter_pipeline(hw):
    H, W = hw
    img = jax.random.uniform(k(42), (H, W)) * 255
    got = ops.filter_pipeline(img, seed=3, block_rows=16)
    want = ref.filter_pipeline_ref(img, seed=3)
    assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_filter_pipeline_is_mirrored():
    img = jnp.tile(jnp.arange(16.0)[None, :], (4, 1))
    out = ops.filter_pipeline(img, noise_scale=0.0)
    # column order must be reversed (values change via solarize only)
    assert float(out[0, 0]) >= float(out[0, -1])


@pytest.mark.parametrize("shape", [(8, 8, 4), (16, 24, 5), (32, 8, 3)])
def test_segmentation(shape):
    v = jax.random.uniform(k(43), shape) * 255
    got = ops.segmentation(v)
    want = ref.segmentation_ref(v)
    assert_allclose(got, want)
    assert set(np.unique(np.asarray(got))) <= {0.0, 128.0, 255.0}


@pytest.mark.parametrize("n", [33, 100, 256])
def test_nbody(n):
    pos = jax.random.normal(k(44), (n, 3))
    mass = jax.random.uniform(k(45), (n,)) + 0.1
    got = ops.nbody_accelerations(pos, mass, block_i=32, block_j=64)
    want = ref.nbody_ref(pos, mass)
    assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_nbody_energy_behaviour():
    """Loop-skeleton integration: momentum is conserved by symmetry."""
    n = 64
    pos = jax.random.normal(k(46), (n, 3))
    vel = jnp.zeros((n, 3))
    mass = jnp.ones((n,))
    p, v = pos, vel
    for _ in range(3):
        p, v = ops.nbody_step(p, v, mass, dt=1e-3)
    total_momentum = np.asarray((mass[:, None] * v).sum(0))
    assert np.abs(total_momentum).max() < 1e-2
