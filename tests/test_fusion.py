"""Cross-request fusion (ISSUE 10).

With ``fusion_window > 0``, identical single-node graphs submitted
within the window coalesce into one wider partitioning — one scheduled
run, one merge — and each request's handle is settled from a slice of
the fused result.  Covers bit-identity against independently-run
requests (clean and under fault injection), the ``fusion_max`` early
flush, the window-expiry single-member fallback, static/dynamic
ineligibility (partition-bound traits, differing scalar values, user
merge functions, undeclared arrays), and ``drain()`` flushing open
batches.
"""
import numpy as np
import pytest

from repro.core import (FaultInjector, JobGraph, ThreadedExecutor, Trait,
                        kernel, scalar, vector)

from test_graph import POLICY, make_scheduler, saxpy_arrays, saxpy_tree


def single_node_graph():
    g = JobGraph()
    g.add(saxpy_tree(), name="s")
    return g


def member_arrays(i, n=256):
    arrays = saxpy_arrays(n)
    arrays["x"] = arrays["x"] + np.float32(i)
    return arrays


def independent_outputs(k, n=256):
    sched = make_scheduler(ThreadedExecutor(policy=POLICY))
    try:
        # np.copy before the next run: merged output buffers are leased
        # and reused across runs (zero-copy pipeline)
        return [np.copy(sched.submit(single_node_graph(),
                                     member_arrays(i, n))
                        .result(30).outputs["z"])
                for i in range(k)]
    finally:
        sched.close()


class TestFusion:
    def test_fused_batch_bit_identical(self):
        expected = independent_outputs(4)
        sched = make_scheduler(ThreadedExecutor(policy=POLICY),
                               fusion_window=5.0, fusion_max=4)
        try:
            handles = [sched.submit(single_node_graph(), member_arrays(i))
                       for i in range(4)]
            results = [h.result(30) for h in handles]
            for r, exp in zip(results, expected):
                np.testing.assert_array_equal(r.outputs["z"], exp)
            assert all(r.runs["s"].action == "fused" for r in results)
            c = sched.counters()
            assert c["scheduler.fused_requests"] == 4
            assert c["scheduler.fused_batches"] == 1
            assert c["scheduler.runs"] == 1
        finally:
            sched.close()

    def test_fusion_max_flushes_early(self):
        """fusion_max members close the batch without waiting for the
        window — with a 30 s window this would time out otherwise."""
        sched = make_scheduler(ThreadedExecutor(policy=POLICY),
                               fusion_window=30.0, fusion_max=3)
        try:
            handles = [sched.submit(single_node_graph(), member_arrays(i))
                       for i in range(3)]
            for h in handles:
                h.result(10)
            assert sched.counters()["scheduler.fused_batches"] == 1
        finally:
            sched.close()

    def test_window_expiry_single_member_falls_back(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY),
                               fusion_window=0.05, fusion_max=8)
        try:
            r = sched.submit(single_node_graph(),
                             member_arrays(0)).result(30)
            assert r.runs["s"].action != "fused"
            np.testing.assert_array_equal(
                r.outputs["z"], 2.0 * np.arange(256, dtype=np.float32) + 1.0)
            assert sched.counters()["scheduler.fused_requests"] == 0
        finally:
            sched.close()

    def test_differing_scalar_values_do_not_fuse(self):
        # reuse_buffers=False: both individual results stay readable
        # after the other run completed
        sched = make_scheduler(ThreadedExecutor(policy=POLICY,
                                                reuse_buffers=False),
                               fusion_window=0.05, fusion_max=2)
        try:
            a2 = saxpy_arrays(256, a=2.0)
            a3 = saxpy_arrays(256, a=3.0)
            h2 = sched.submit(single_node_graph(), a2)
            h3 = sched.submit(single_node_graph(), a3)
            x = np.arange(256, dtype=np.float32)
            np.testing.assert_array_equal(h2.result(30).outputs["z"],
                                          2.0 * x + 1.0)
            np.testing.assert_array_equal(h3.result(30).outputs["z"],
                                          3.0 * x + 1.0)
            assert sched.counters()["scheduler.fused_requests"] == 0
        finally:
            sched.close()

    def test_partition_bound_trait_is_ineligible(self):
        """A SIZE-trait scalar is bound to the partition geometry; a
        fused (wider) run would feed members the wrong value."""
        sct = kernel(lambda x, n: x + np.float32(n), name="plusn",
                     inputs=[vector("x"), scalar("n", trait=Trait.SIZE)],
                     outputs=[vector("z")])
        sched = make_scheduler(ThreadedExecutor(policy=POLICY),
                               fusion_window=0.05, fusion_max=2)
        try:
            arrays = {"x": np.arange(256, dtype=np.float32)}
            handles = []
            for _ in range(2):
                g = JobGraph()
                g.add(sct, name="s")
                handles.append(sched.submit(g, dict(arrays)))
            for h in handles:
                r = h.result(30)
                assert r.runs["s"].action != "fused"
            assert sched.counters()["scheduler.fused_requests"] == 0
        finally:
            sched.close()

    def test_user_merge_is_ineligible(self):
        """Any user merge on a produced output defeats output slicing,
        so the request must run unfused (the merge itself is the
        default concatenation, keeping the individual path valid)."""
        sched = make_scheduler(
            ThreadedExecutor(policy=POLICY,
                             merges={"z": lambda parts:
                                     np.concatenate(parts)}),
            fusion_window=0.05, fusion_max=2)
        try:
            handles = [sched.submit(single_node_graph(), member_arrays(i))
                       for i in range(2)]
            for h in handles:
                assert h.result(30).runs["s"].action != "fused"
            assert sched.counters()["scheduler.fused_requests"] == 0
        finally:
            sched.close()

    def test_undeclared_arrays_are_ineligible(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY),
                               fusion_window=0.05, fusion_max=2)
        try:
            handles = []
            for i in range(2):
                arrays = member_arrays(i)
                arrays["junk"] = np.zeros(4, dtype=np.float32)
                handles.append(sched.submit(single_node_graph(), arrays))
            for h in handles:
                assert h.result(30).runs["s"].action != "fused"
            assert sched.counters()["scheduler.fused_requests"] == 0
        finally:
            sched.close()

    def test_fused_bit_identical_under_fault_injection(self):
        expected = independent_outputs(4)
        inj = FaultInjector(crash_on_call={"gpu0": [1]})
        sched = make_scheduler(ThreadedExecutor(policy=POLICY,
                                                injector=inj),
                               fusion_window=5.0, fusion_max=4)
        try:
            handles = [sched.submit(single_node_graph(), member_arrays(i))
                       for i in range(4)]
            results = [h.result(30) for h in handles]
            for r, exp in zip(results, expected):
                np.testing.assert_array_equal(r.outputs["z"], exp)
            assert all(r.runs["s"].action == "fused" for r in results)
            # the crash was contained inside the single fused run
            assert any(r.runs["s"].stats.retries for r in results)
            assert sched.counters()["scheduler.fused_requests"] == 4
        finally:
            sched.close()

    def test_drain_flushes_open_batches(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY),
                               fusion_window=30.0, fusion_max=8)
        try:
            h = sched.submit(single_node_graph(), member_arrays(0))
            assert sched.drain(20)
            assert h.done()
            np.testing.assert_array_equal(
                h.result(0).outputs["z"],
                2.0 * np.arange(256, dtype=np.float32) + 1.0)
        finally:
            sched.close()
