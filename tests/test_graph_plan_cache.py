"""Whole-graph plan cache (ISSUE 10).

A second submission of a structurally identical graph with identical
array signatures is served pre-planned: every node dispatches with the
recorded ``NodePlan``, acquiring neither the decide lock nor the plan
lock, and producing bit-identical outputs.  Invalidation paths — a
device-health transition, an explicit plan-cache invalidation, a
faulted/retried node — fall back to ordinary per-node planning.  Also
covers the satellite regression: repeated identical single-node graphs
hit the per-node plan cache at >= 7/8.
"""
import numpy as np
import pytest

from repro.core import (FaultInjector, JobGraph, SimulatedExecutor,
                        ThreadedExecutor, kernel, scalar, vector)

from test_graph import (POLICY, chain_trees, make_scheduler, make_sim,
                        saxpy_arrays, saxpy_tree)


def lock_counts(sched):
    c = sched.counters()
    return (c["scheduler.decide_locks"], c["scheduler.plan_locks"])


def single_node_graph():
    g = JobGraph()
    g.add(saxpy_tree(), name="s")
    return g


def chain_graph():
    g = JobGraph()
    prev = None
    for i, sct in enumerate(chain_trees()):
        prev = g.add(sct, name=f"n{i}",
                     after=(prev,) if prev is not None else ())
    return g


class TestGraphPlanCache:
    def test_second_submission_preplanned_zero_locks(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        try:
            arrays = saxpy_arrays(512)
            r1 = sched.submit(single_node_graph(), arrays).result(30)
            z1 = np.copy(r1.outputs["z"])   # merge buffers are reused
            locks0 = lock_counts(sched)
            r2 = sched.submit(single_node_graph(), arrays).result(30)
            locks1 = lock_counts(sched)
            # the pre-planned hit path acquires neither scheduler lock
            assert locks1 == locks0
            assert [r.action for r in r2.runs.values()] == ["preplanned"]
            np.testing.assert_array_equal(z1, r2.outputs["z"])
            c = sched.plan_cache.counters()
            assert c["graph_hits"] == 1 and c["graph_misses"] == 1
        finally:
            sched.close()

    def test_chain_graph_preplanned_bit_identical(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        try:
            arrays = saxpy_arrays(512)
            r1 = sched.submit(chain_graph(), arrays).result(30)
            v1 = np.copy(r1.outputs["v"])
            locks0 = lock_counts(sched)
            r2 = sched.submit(chain_graph(), arrays).result(30)
            assert lock_counts(sched) == locks0
            assert all(r.action == "preplanned" for r in r2.runs.values())
            np.testing.assert_array_equal(v1, r2.outputs["v"])
        finally:
            sched.close()

    def test_array_signature_in_key(self):
        """A different input shape is a different graph-plan key."""
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        try:
            sched.submit(single_node_graph(), saxpy_arrays(256)).result(30)
            r = sched.submit(single_node_graph(),
                             saxpy_arrays(512)).result(30)
            assert all(x.action != "preplanned" for x in r.runs.values())
            assert sched.plan_cache.counters()["graph_misses"] == 2
        finally:
            sched.close()

    def test_health_movement_drops_plan(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        try:
            arrays = saxpy_arrays(512)
            r1 = sched.submit(single_node_graph(), arrays).result(30)
            z1 = np.copy(r1.outputs["z"])
            for _ in range(sched.health.quarantine_after):
                sched.health.record_failure("gpu0")
            assert sched.health.version > 0
            r2 = sched.submit(single_node_graph(), arrays).result(30)
            # stale health version: entry dropped, node planned afresh
            assert all(x.action != "preplanned" for x in r2.runs.values())
            np.testing.assert_array_equal(z1, r2.outputs["z"])
        finally:
            sched.close()

    def test_explicit_invalidation_forces_replan(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        try:
            arrays = saxpy_arrays(512)
            sched.submit(single_node_graph(), arrays).result(30)
            sched.plan_cache.invalidate("test")
            r = sched.submit(single_node_graph(), arrays).result(30)
            assert all(x.action != "preplanned" for x in r.runs.values())
            assert sched.plan_cache.counters()["graph_misses"] == 2
        finally:
            sched.close()

    def test_faulted_graph_is_not_recorded(self):
        inj = FaultInjector(crash_on_call={"gpu0": [1]})
        sched = make_scheduler(ThreadedExecutor(policy=POLICY,
                                                injector=inj))
        try:
            arrays = saxpy_arrays(512)
            r1 = sched.submit(single_node_graph(), arrays).result(30)
            assert any(x.stats.retries for x in r1.runs.values())
            # the in-run repartition marked the plan dirty: no recording
            r2 = sched.submit(single_node_graph(), arrays).result(30)
            assert all(x.action != "preplanned" for x in r2.runs.values())
            assert sched.plan_cache.counters()["graph_misses"] == 2
        finally:
            sched.close()

    def test_disabled_cache_never_preplans(self):
        sched = make_scheduler(ThreadedExecutor(policy=POLICY),
                               plan_cache=False)
        try:
            arrays = saxpy_arrays(512)
            for _ in range(2):
                r = sched.submit(single_node_graph(), arrays).result(30)
                assert all(x.action != "preplanned"
                           for x in r.runs.values())
        finally:
            sched.close()

    def test_virtual_path_preplanned_and_deterministic(self):
        arrays = saxpy_arrays(4096)
        sched = make_scheduler(make_sim())
        try:
            r1 = sched.submit(single_node_graph(), arrays).result(30)
            z1 = np.copy(r1.outputs["z"])
            r2 = sched.submit(single_node_graph(), arrays).result(30)
            assert [x.action for x in r2.runs.values()] == ["preplanned"]
            np.testing.assert_array_equal(z1, r2.outputs["z"])
        finally:
            sched.close()


class TestPlanCacheHitRate:
    def test_repeated_identical_single_node_hit_rate(self):
        """Satellite regression: 8 identical single-node submissions
        must hit the per-node plan cache at least 7 times (the seed
        pipeline showed 7/8 *misses* from per-request key churn)."""
        sched = make_scheduler(ThreadedExecutor(policy=POLICY))
        try:
            arrays = saxpy_arrays(1024)
            for _ in range(8):
                sched.submit(single_node_graph(), arrays).result(30)
            pc = sched.plan_cache
            assert pc.misses == 1
            assert pc.hits >= 7
            assert pc.hit_rate >= 7 / 8
        finally:
            sched.close()
