"""Fig 11 — adaptation to CPU load fluctuations (FFT-128).

Initial distribution ~ (GPU 75.5%, CPU 24.5%); an external application
then loads the CPU (simulator ``set_cpu_load``).  The monitor detects
the unbalance (lbt crosses the trigger after 3-4 runs) and the adaptive
binary search shifts work to the GPU — the paper observes an abrupt
1-4-run shifting phase followed by ~10 runs of smooth halving.
"""
from __future__ import annotations

import math
from typing import List

from benchmarks.hybrid import make_scheduler
from benchmarks.paper_suite import BENCHMARKS, workload_for
from repro.core import LoadBalancer
from repro.core.distribution import Distribution
from repro.core.knowledge_base import Origin, PlatformConfig, Profile
from repro.core.load_balancer import class_times


def main(full: bool = False) -> List[str]:
    name, size = "fft", 128
    sct = BENCHMARKS[name][0](size)
    workload = workload_for(name, size)
    sched, sim = make_scheduler(name, size, n_gpus=1)
    arrays = sim.synthesise_arrays(sct, workload)
    prof = Profile(sct_id=sct.unique_id(), workload=workload,
                   share_a=0.755,
                   config=PlatformConfig(fission_level="L3", overlap=4))
    balancer = LoadBalancer(max_dev=0.85)
    runs = 60 if full else 40
    load_at, load_off = 10, runs - 15
    print("== load-fluctuation adaptation (Fig 11, FFT-128) ==")
    print(f"{'run':>4s} {'cpu load':>8s} {'gpu%':>6s} {'dev':>6s} "
          f"{'balanced?':>9s}")
    trace: List[float] = []
    cur = prof
    for run in range(runs):
        sim.set_cpu_load(3.0 if load_at <= run < load_off else 0.0)
        _, stats, _, _, _ = sched._dispatch(sct, arrays, cur)
        trig = balancer.observe(stats)
        if trig:
            n_a = sum(1 for s in sched._slots(cur)
                      if s.device_type != "cpu")
            ta, tb = class_times(stats.times, n_a)
            new = balancer.adjust(
                Distribution(a=cur.share_a, b=1 - cur.share_a), ta, tb)
            cur = Profile(sct_id=cur.sct_id, workload=workload,
                          share_a=new.a, config=cur.config,
                          best_time=math.inf, origin=Origin.DERIVED)
        else:
            balancer.balanced_again()
        trace.append(cur.share_a)
        if run % (2 if not full else 1) == 0:
            print(f"{run:>4d} {sim.cpu_load:>8.1f} "
                  f"{100 * cur.share_a:>6.1f} {stats.deviation:>6.2f} "
                  f"{'no' if trig else 'yes':>9s}")
    before = trace[load_at - 1]
    peak = max(trace[load_at:load_off])
    after = trace[-1]
    print(f"gpu share: {100 * before:.1f}% -> {100 * peak:.1f}% under "
          f"load -> {100 * after:.1f}% after")
    assert peak > before + 0.05, "balancer failed to shift work to GPU"
    return [f"load_fluctuation,fft,128,{before:.3f},{peak:.3f},"
            f"{after:.3f}"]


if __name__ == "__main__":
    main(full=True)
