"""Benchmark harness — one module per paper table/figure.

  fission.py              Table 2 / Fig 6   CPU-only device fission
  profile_construction.py Fig 5             Algorithm-1 search trace
  hybrid.py               Table 3 / Figs 7-8 CPU+GPU vs GPU-only
  maxdev.py               Table 4           maxDev calibration
  kb_derivation.py        Table 5 / Figs 9-10 KB-derived vs built profiles
  load_fluctuation.py     Fig 11            adaptation to CPU load
  roofline.py             (this work)       40-cell roofline + §Perf

``python -m benchmarks.run`` executes all and prints a CSV summary.
Scheduling-policy numbers come from the calibrated simulator (single-core
container; see DESIGN.md §7); kernel-level numbers are real timed runs.
"""
