"""Table 4 — maxDev calibration under stable load.

500 executions of each benchmark on the stable (simulated) testbed; the
reported value is the *minimum* per-run deviation observed — setting
maxDev below it keeps the load balancer quiet under stable conditions.
Paper conclusion: [0.8, 0.85] is an adequate range.
"""
from __future__ import annotations

import math
from typing import List

from benchmarks.hybrid import make_scheduler, tune_cell
from benchmarks.paper_suite import BENCHMARKS, workload_for
from repro.core import ExecutionStats, TunerParams, build_profile
from repro.core.distribution import Distribution
from repro.core.knowledge_base import PlatformConfig, Profile
from repro.core.load_balancer import class_times

CASES = [("saxpy", 10 ** 7), ("filter_pipeline", 4096), ("fft", 256),
         ("segmentation", 512)]


def main(full: bool = False) -> List[str]:
    runs = 500 if full else 120
    print(f"== maxDev calibration (Table 4, {runs} runs each) ==")
    lines = []
    for name, size in CASES:
        sct = BENCHMARKS[name][0](size)
        workload = workload_for(name, size)
        sched, sim = make_scheduler(name, size, n_gpus=1)
        arrays = sim.synthesise_arrays(sct, workload)

        # the paper measures deviation under the *tuned* configuration
        def evaluate(cfg: PlatformConfig, dist: Distribution):
            pr = Profile(sct_id=sct.unique_id(), workload=workload,
                         share_a=dist.a, config=cfg)
            _, st, _, _, _ = sched._dispatch(sct, arrays, pr)
            n_a = sum(1 for sl in sched._slots(pr)
                      if sl.device_type != "cpu")
            ta, tb = class_times(st.times, n_a)
            return st.total, ta, tb

        prof = build_profile(sct.unique_id(), workload, host=sched.host,
                             accel=sched.accel, evaluate=evaluate,
                             params=TunerParams(number_executions=1)
                             ).profile
        worst = 1.0
        for _ in range(runs):
            _, stats, _, _, _ = sched._dispatch(sct, arrays, prof)
            worst = min(worst, stats.deviation)
        print(f"{name:18s} {size:>9d}  min deviation {worst:.3f} "
              f"(paper range: 0.825-0.979)")
        lines.append(f"maxdev,{name},{size},{worst:.4f}")
    return lines


if __name__ == "__main__":
    main(full=True)
