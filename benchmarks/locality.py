"""Locality benchmark: plan cache + persistent pools + zero-copy merge.

Measures the non-compute dispatch overhead (plan + pool + dispatch +
merge) of recurrent runs and of a 3-kernel compound chain, comparing:

  * **baseline** — the historical dispatch path: plan cache off,
    per-attempt thread pools, ``np.concatenate`` merge
    (``Scheduler(plan_cache=False)`` +
    ``ThreadedExecutor(persistent_pool=False, inplace_merge=False)``);
  * **optimized** — the locality pipeline: plan/partitioning cache,
    persistent worker pool, in-place merge into reusable buffers, and
    ``run_chain`` partitioned residency between chained kernels.

Emits ``BENCH_locality.json``.  ``--check`` gates the *deterministic*
acceptance counters (CI smoke job):

  * ``resident_merge_bytes == 0`` — zero bytes copied at merge on the
    resident-chain path;
  * ``plan_cache_hit_rate >= 0.8`` over the recurrent phase;
  * bit-identical outputs vs. the baseline merge implementation, with
    and without an injected fault (repartition path).

The measured overhead reduction is reported in the JSON (the issue's
≥2x target) but not CI-gated: wall-clock ratios on shared runners are
too noisy to fail a build on.

Run:  PYTHONPATH=src python benchmarks/locality.py [--smoke] [--check]
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import numpy as np

from repro.core import (AcceleratorPlatform, DeviceInfo, FaultInjector,
                        FaultPolicy, HostPlatform, KnowledgeBase,
                        LoadBalancer, Origin, PlatformConfig, Profile,
                        Scheduler, Telemetry, ThreadedExecutor,
                        infer_workload, kernel, scalar, vector)

try:
    from benchmarks.report import embed_metrics
except ImportError:                     # run as `python benchmarks/...`
    from report import embed_metrics

# a huge watchdog multiple disables spurious timeout trips on busy CI
POLICY = FaultPolicy(watchdog_multiple=1e6)


def chain_kernels():
    k1 = kernel(lambda a, x, y: a * x + y, name="saxpy",
                inputs=[scalar("a"), vector("x"), vector("y")],
                outputs=[vector("z")])
    k2 = kernel(lambda a, z: z * a, name="scale",
                inputs=[scalar("a"), vector("z")], outputs=[vector("w")])
    k3 = kernel(lambda w, y: w + y, name="addy",
                inputs=[vector("w"), vector("y")], outputs=[vector("v")])
    return [k1, k2, k3]


def make_arrays(n: int):
    return {"a": np.float32(2.0),
            "x": np.arange(n, dtype=np.float32),
            "y": np.ones(n, dtype=np.float32)}


def make_scheduler(*, optimized: bool, injector=None,
                   telemetry=None) -> Scheduler:
    host = HostPlatform(DeviceInfo("cpu0", "cpu", compute_units=4),
                        topology={"L2": 2, "NO_FISSION": 1})
    accel = AcceleratorPlatform([DeviceInfo("gpu0", "gpu")], max_overlap=2)
    ex = ThreadedExecutor(policy=POLICY, injector=injector,
                          persistent_pool=optimized,
                          inplace_merge=optimized,
                          reuse_buffers=optimized)
    sched = Scheduler(host=host, accel=accel, executor=ex,
                      kb=KnowledgeBase(),
                      balancer=LoadBalancer(max_dev=0.0),
                      plan_cache=optimized, telemetry=telemetry)
    # pre-store fission profiles so both legs run the same slot layout
    # and no watchdog deadline applies (best_time stays infinite)
    for sct in chain_kernels():
        wl = infer_workload(sct, make_arrays(ARGS.n),
                            shapes={"z": (ARGS.n,), "w": (ARGS.n,)})
        sched.kb.store(Profile(
            sct_id=sct.unique_id(), workload=wl, share_a=0.5,
            config=PlatformConfig(fission_level="L2"),
            best_time=float("inf"), origin=Origin.DERIVED))
    return sched


def run_sequential(sched: Scheduler, arrays, copy_out: bool):
    """Chain the kernels through full merges (the baseline data path)."""
    env = dict(arrays)
    overheads = []
    for sct in chain_kernels():
        r = sched.run(sct, env)
        env.update({k: (np.copy(v) if copy_out else v)
                    for k, v in r.outputs.items()})
        overheads.append(r.stats.overhead_seconds)
    return env["v"], sum(overheads)


def bench(smoke: bool):
    global ARGS
    reps = 5 if smoke else 9
    warmup = 2

    arrays = make_arrays(ARGS.n)

    # -- recurrent single-SCT phase -----------------------------------------
    telemetry = Telemetry()      # shared by every optimized-leg scheduler
    base = make_scheduler(optimized=False)
    opt = make_scheduler(optimized=True, telemetry=telemetry)
    sct = chain_kernels()[0]
    base_over, opt_over = [], []
    for sched, sink in ((base, base_over), (opt, opt_over)):
        for _ in range(warmup):
            sched.run(sct, dict(arrays))
        for _ in range(reps):
            r = sched.run(sct, dict(arrays))
            sink.append(r.stats.overhead_seconds)
    hit_rate = opt.plan_cache.hit_rate

    # -- compound-chain phase ------------------------------------------------
    base_c = make_scheduler(optimized=False)
    opt_c = make_scheduler(optimized=True, telemetry=telemetry)
    expected, _ = run_sequential(base_c, arrays, copy_out=True)
    base_chain, opt_chain = [], []
    resident_bytes = []
    for _ in range(warmup):
        opt_c.run_chain(chain_kernels(), dict(arrays))
    for _ in range(reps):
        _, o = run_sequential(base_c, arrays, copy_out=True)
        base_chain.append(o)
        runs = opt_c.run_chain(chain_kernels(), dict(arrays))
        opt_chain.append(sum(r.stats.overhead_seconds for r in runs))
        resident_bytes.extend(r.stats.merge_bytes for r in runs
                              if r.stats.resident)
    got = np.copy(np.asarray(runs[-1].outputs["v"]))
    bit_identical = bool(np.array_equal(expected, got))

    # -- fault-injected chain (repartition fallback) -------------------------
    inj = FaultInjector(crash_on_call={"gpu0": [1]})
    faulted = make_scheduler(optimized=True, injector=inj,
                             telemetry=telemetry)
    fruns = faulted.run_chain(chain_kernels(), dict(arrays))
    bit_identical_faulted = bool(np.array_equal(
        expected, np.copy(np.asarray(fruns[-1].outputs["v"]))))
    faulted_retries = sum(r.stats.retries for r in fruns)

    med = statistics.median
    result = {
        "bench": "locality", "smoke": smoke, "n": ARGS.n, "reps": reps,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "recurrent": {
            "baseline_overhead_s": med(base_over),
            "optimized_overhead_s": med(opt_over),
            "overhead_reduction_x": (med(base_over) / med(opt_over)
                                     if med(opt_over) > 0 else float("inf")),
            "plan_cache": opt.plan_cache.counters(),
            "pools_created": opt.executor.pools_created,
            "pool_reuses": opt.executor.pool_reuses,
        },
        "chain": {
            "baseline_overhead_s": med(base_chain),
            "optimized_overhead_s": med(opt_chain),
            "overhead_reduction_x": (med(base_chain) / med(opt_chain)
                                     if med(opt_chain) > 0 else float("inf")),
            "resident_merge_bytes": int(max(resident_bytes))
            if resident_bytes else -1,
            "resident_steps_per_chain": sum(
                1 for r in runs if r.stats.resident),
        },
        "plan_cache_hit_rate": hit_rate,
        "bit_identical": bit_identical,
        "bit_identical_faulted": bit_identical_faulted,
        "faulted_retries": faulted_retries,
    }
    return embed_metrics(result, telemetry)


def check(result) -> int:
    failures = []
    if result["chain"]["resident_merge_bytes"] != 0:
        failures.append("resident-chain path copied bytes at merge: "
                        f"{result['chain']['resident_merge_bytes']}")
    if result["plan_cache_hit_rate"] < 0.8:
        failures.append("plan-cache hit rate regressed: "
                        f"{result['plan_cache_hit_rate']:.2f} < 0.8")
    if not result["bit_identical"]:
        failures.append("optimized outputs differ from baseline merge")
    if not result["bit_identical_faulted"]:
        failures.append("fault-injected outputs differ from baseline merge")
    if result["faulted_retries"] < 1:
        failures.append("fault injection did not exercise the retry path")
    for f in failures:
        print(f"CHECK FAILED: {f}")
    return 1 if failures else 0


def main():
    global ARGS
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small workload / few reps (CI)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero if acceptance counters regress")
    ap.add_argument("--out", default="BENCH_locality.json")
    ap.add_argument("--n", type=int, default=None,
                    help="vector length (default: 1<<19 smoke, 1<<20 full)")
    ARGS = ap.parse_args()
    if ARGS.n is None:
        ARGS.n = (1 << 19) if ARGS.smoke else (1 << 20)

    result = bench(ARGS.smoke)
    with open(ARGS.out, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))
    print(f"wrote {ARGS.out}")
    if ARGS.check:
        raise SystemExit(check(result))


if __name__ == "__main__":
    main()
