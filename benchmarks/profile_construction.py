"""Fig 5 — execution times measured during profile construction.

Reproduces the FFT-256MB trace: every (fission, overlap, distribution)
configuration Algorithm 1 times on the hybrid testbed, in search order,
showing the ordered-and-pruned walk towards the optimum.
"""
from __future__ import annotations

import math
from typing import List

from benchmarks.hybrid import make_scheduler
from benchmarks.paper_suite import BENCHMARKS, workload_for
from repro.core import TunerParams, build_profile
from repro.core.distribution import Distribution
from repro.core.knowledge_base import PlatformConfig, Profile


def main(full: bool = False) -> List[str]:
    name, size = "fft", 256
    sct = BENCHMARKS[name][0](size)
    workload = workload_for(name, size)
    sched, sim = make_scheduler(name, size, n_gpus=1)
    arrays = sim.synthesise_arrays(sct, workload)

    def evaluate(cfg: PlatformConfig, dist: Distribution):
        prof = Profile(sct_id=sct.unique_id(), workload=workload,
                       share_a=dist.a, config=cfg, best_time=math.inf)
        _, stats, _, _, _ = sched._dispatch(sct, arrays, prof)
        n_a = sum(1 for s in sched._slots(prof) if s.device_type != "cpu")
        ta = max(stats.times[:n_a]) if n_a else 0.0
        tb = max(stats.times[n_a:]) if len(stats.times) > n_a else 0.0
        return stats.total, ta, tb

    res = build_profile(sct.unique_id(), workload, host=sched.host,
                        accel=sched.accel, evaluate=evaluate,
                        params=TunerParams(number_executions=1))
    print("== profile construction trace (Fig 5, FFT-256) ==")
    print(f"{'#':>3s} {'fission':>9s} {'overlap':>7s} {'gpu%':>6s} "
          f"{'time':>9s}")
    step = max(1, len(res.trace) // (40 if not full else len(res.trace)))
    for i, t in enumerate(res.trace):
        if i % step == 0 or i == len(res.trace) - 1:
            print(f"{i:>3d} {t.fission_level:>9s} {t.overlap:>7d} "
                  f"{100 * t.distribution:>5.1f} {t.time:>9.4f}")
    best = res.profile
    print(f"best: fission={best.config.fission_level} "
          f"overlap={best.config.overlap} gpu={best.share_a:.2f} "
          f"t={best.best_time:.4f} ({res.evaluations} evaluations)")
    return [f"profile_construction,fft,256,{res.evaluations},"
            f"{best.best_time:.5f},{best.config.fission_level},"
            f"{best.config.overlap}"]


if __name__ == "__main__":
    main(full=True)
