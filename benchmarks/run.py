"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

``--full`` runs the complete parameterisation classes (slower);
the default exercises every benchmark end-to-end at reduced size.
Prints a ``name,...`` CSV block at the end for machine consumption.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="run a single module (e.g. 'hybrid')")
    args = ap.parse_args()

    from benchmarks import (fission, hybrid, kb_derivation,
                            load_fluctuation, maxdev, profile_construction,
                            roofline)
    modules = {
        "fission": fission,
        "profile_construction": profile_construction,
        "hybrid": hybrid,
        "maxdev": maxdev,
        "kb_derivation": kb_derivation,
        "load_fluctuation": load_fluctuation,
        "roofline": roofline,
    }
    if args.only:
        modules = {args.only: modules[args.only]}

    all_lines = []
    for name, mod in modules.items():
        t0 = time.time()
        try:
            lines = mod.main(full=args.full)
            all_lines.extend(lines or [])
            print(f"-- {name} done in {time.time() - t0:.1f}s --\n")
        except Exception as e:           # keep the harness going
            print(f"-- {name} FAILED: {e!r} --\n")
            all_lines.append(f"{name},FAILED,{e!r}")
            import traceback
            traceback.print_exc()
            return 1

    print("==== CSV summary ====")
    for line in all_lines:
        print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
