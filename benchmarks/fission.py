"""Table 2 / Fig 6 — device-fission speedups, CPU-only executions.

Two measurements:
  (a) *simulated* Opteron testbed (the paper's 64-core 4-socket box,
      calibrated cache hierarchy) — reproduces Table 2's fission-level
      selection and Fig 6's fission/no-fission speedups;
  (b) *real timed* partition-count sweep on this host (single core:
      the locality effect without the parallelism term).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.paper_suite import (BENCHMARKS, cost_model_for,
                                    opteron_testbed, workload_for)
from repro.core import (AcceleratorPlatform, DeviceInfo, HostPlatform,
                        KnowledgeBase, Scheduler)
from repro.core.knowledge_base import PlatformConfig, Profile
from repro.core.platforms import FISSION_LEVELS
from repro.core.simulator import SimulatedExecutor
from repro.core.spec import Workload

#: paper Sec. 4.1 topology: 64 cores; L2 pairs -> 32, L3 islands -> 8,
#: NUMA sockets -> 4
OPTERON_TOPOLOGY = {"L1": 64, "L2": 32, "L3": 8, "NUMA": 4,
                    "NO_FISSION": 1}

#: paper Table 2 best-fission results (level, speedup vs no fission)
PAPER_TABLE2 = {
    ("filter_pipeline", 2048): ("L2", 34.8 / 22.0),
    ("filter_pipeline", 4096): ("L2", 120.3 / 65.1),
    ("fft", 256): ("L2", 197.9 / 56.5),
    ("nbody", 16384): ("L3", 284.0 / 99.0),
    ("saxpy", 10 ** 7): ("L2", 72.1 / 23.9),
    ("segmentation", 512): ("L3", 11.8 / 4.3),
}


def simulate_fission(name: str, size: int) -> Dict:
    """Best fission level + speedup on the calibrated Opteron box."""
    sct = BENCHMARKS[name][0](size)
    host = HostPlatform(DeviceInfo("cpu", "cpu", compute_units=64),
                        topology=OPTERON_TOPOLOGY)
    accel = AcceleratorPlatform([DeviceInfo("null", "gpu")])  # unused
    from repro.core.simulator import SimDevice
    devs = opteron_testbed() + [SimDevice("null", "gpu", flops=1.0)]
    sim = SimulatedExecutor(devs, seed=0,
                            cost=cost_model_for(name, size))
    sched = Scheduler(host=host, accel=accel, executor=sim,
                      kb=KnowledgeBase(), default_share_a=0.0)
    workload = workload_for(name, size)
    times: Dict[str, float] = {}
    for level in FISSION_LEVELS:
        if level not in OPTERON_TOPOLOGY:
            continue
        prof = Profile(sct_id=sct.unique_id(), workload=workload,
                       share_a=0.0,
                       config=PlatformConfig(fission_level=level))
        _, stats, _, _, _ = sched._dispatch(sct, _arrays(sct, workload), prof)
        times[level] = stats.total
    best = min(times, key=times.get)
    return {"benchmark": name, "size": size, "best_level": best,
            "speedup_vs_nofission": times["NO_FISSION"] / times[best],
            "times": times}


def _arrays(sct, workload: Workload):
    sim_exec = SimulatedExecutor(opteron_testbed())
    return sim_exec.synthesise_arrays(sct, workload)


def timed_partition_sweep() -> List[Dict]:
    """Real timed saxpy/segmentation partitioned runs on this host."""
    import jax.numpy as jnp
    from repro.core import ExecutionSlot, ThreadedExecutor, build_plan
    from repro.core.knowledge_base import PlatformConfig, Profile
    out = []
    n = 1 << 20
    x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    y = np.ones(n, np.float32)
    from benchmarks.paper_suite import saxpy_sct
    sct = saxpy_sct()
    plan = build_plan(sct, {"x": (n,), "y": (n,), "z": (n,)})
    ex = ThreadedExecutor()
    for parts in (1, 2, 4, 8):
        slots = [ExecutionSlot(f"c{i}", "cpu") for i in range(parts)]
        part = plan.partition(slots, [1.0 / parts] * parts)
        arrays = {"a": np.float32(2.0), "x": x, "y": y}
        t0 = time.perf_counter()
        for _ in range(3):
            outs, _ = ex.execute(sct, part, arrays,
                                 Profile("s", Workload((n,)), 0.0,
                                         PlatformConfig()))
        dt = (time.perf_counter() - t0) / 3
        np.testing.assert_allclose(outs["z"], 2 * x + y, rtol=1e-5)
        out.append({"partitions": parts, "seconds": dt})
    return out


def main(full: bool = True) -> List[str]:
    lines = []
    print("== fission (Table 2 / Fig 6) ==")
    print(f"{'benchmark':18s} {'size':>9s} {'sim best':>9s} "
          f"{'paper':>6s} {'sim speedup':>11s} {'paper':>6s}")
    for (name, size), (paper_level, paper_speedup) in PAPER_TABLE2.items():
        r = simulate_fission(name, size)
        print(f"{name:18s} {size:>9d} {r['best_level']:>9s} "
              f"{paper_level:>6s} {r['speedup_vs_nofission']:>11.2f} "
              f"{paper_speedup:>6.2f}")
        lines.append(f"fission,{name},{size},{r['best_level']},"
                     f"{r['speedup_vs_nofission']:.3f}")
    for r in timed_partition_sweep():
        print(f"  [real] saxpy 1M x{r['partitions']:d} partitions: "
              f"{r['seconds'] * 1e3:.1f} ms")
        lines.append(f"fission_real,saxpy,{r['partitions']},"
                     f"{r['seconds'] * 1e6:.0f}us")
    return lines


if __name__ == "__main__":
    main()
