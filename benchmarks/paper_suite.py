"""The paper's five benchmarks as Marrow SCTs + calibrated testbeds.

Benchmarks (paper Sec. 4): Filter Pipeline (Pipeline), FFT (Pipeline),
N-Body (Loop, COPY dataset), Saxpy (Map), Segmentation (Map, 3-D).
``flops/bytes_per_item`` calibrate the simulator's cost model; the
elementary partitioning units mirror the paper exactly (image line, one
FFT, one body, one element, one plane).

Testbeds:
  * OPTERON — Sec. 4.1: 4x 16-core AMD Opteron 6272 (CPU-only),
    16 KiB L1 / 2 MiB L2 per 2 cores / 6 MiB L3 per 8 cores.
  * HYBRID  — Sec. 4.2: i7-3930K (6C12T) + 1-2x AMD HD 7950 on PCIe x16.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.core import (KernelSpec, Loop, LoopState, Map, MapReduce,
                        Pipeline, SCT, kernel, scalar, vector)
from repro.core.simulator import CostModel, SimDevice
from repro.core.spec import Trait, Workload


# ---------------------------------------------------------------------------
# SCT builders (jnp bodies are real; the simulator uses only the specs)
# ---------------------------------------------------------------------------

def filter_pipeline_sct(width: int = 1024) -> SCT:
    """Gaussian Noise -> Solarize -> Mirror; epu = image line, nu = 2."""
    import jax.numpy as jnp

    def noise(img):
        h = (jnp.arange(img.shape[0])[:, None] * 31
             + jnp.arange(img.shape[1])[None, :] * 17) % 13
        return jnp.clip(img + (h.astype(img.dtype) - 6.0), 0, 255)

    k1 = kernel(noise, name="gauss_noise",
                inputs=[vector("img", epu=1)],
                outputs=[vector("noisy", epu=1)],  # 2 px/thread is intra-line
                flops_per_item=6 * width, bytes_per_item=8 * width)
    k2 = kernel(lambda x: np_where_solarize(x), name="solarize",
                inputs=[vector("noisy", epu=1)],
                outputs=[vector("sol", epu=1)],
                flops_per_item=2 * width, bytes_per_item=8 * width)
    k3 = kernel(lambda x: x[:, ::-1], name="mirror",
                inputs=[vector("sol", epu=1)],
                outputs=[vector("out", epu=1)],
                flops_per_item=1 * width, bytes_per_item=8 * width)
    return Pipeline(k1, k2, k3)


def np_where_solarize(x):
    import jax.numpy as jnp
    return jnp.where(x > 128.0, 255.0 - x, x)


FFT_ELEMS = 512 * 1024 // 8        # one 512 KiB FFT (f64 complex pairs)


def fft_sct() -> SCT:
    """FFT -> iFFT pipeline; epu = one whole FFT (paper: 512 KiB)."""
    import jax.numpy as jnp
    lg = math.log2(FFT_ELEMS)
    k1 = kernel(lambda x: jnp.real(jnp.fft.fft(x, axis=1)).astype(x.dtype),
                name="fft", inputs=[vector("sig", epu=1)],
                outputs=[vector("freq", epu=1)],
                flops_per_item=5 * FFT_ELEMS * lg,
                bytes_per_item=16 * FFT_ELEMS)
    k2 = kernel(lambda x: jnp.real(jnp.fft.ifft(x, axis=1)).astype(x.dtype),
                name="ifft", inputs=[vector("freq", epu=1)],
                outputs=[vector("sig_out", epu=1)],
                flops_per_item=5 * FFT_ELEMS * lg,
                bytes_per_item=16 * FFT_ELEMS)
    return Pipeline(k1, k2)


def nbody_sct(n_bodies: int, iterations: int = 1) -> SCT:
    """Direct-sum N-Body; COPY dataset, partitioned at body level."""
    import jax.numpy as jnp

    def step(mine, all_pos):
        d = all_pos[None, :, :3] - mine[:, None, :3]
        r2 = (d * d).sum(-1) + 1e-3
        acc = (d / (r2 ** 1.5)[..., None]).sum(1)
        return mine.at[:, :3].add(0.001 * acc) if hasattr(mine, "at") \
            else mine

    body = kernel(step, name="nbody_step",
                  inputs=[vector("bodies", epu=1),
                          vector("all_bodies", copy=True)],
                  outputs=[vector("bodies", epu=1)],
                  flops_per_item=20.0 * n_bodies,
                  bytes_per_item=16.0)
    return Loop(body, LoopState(max_iterations=iterations,
                                global_sync=True))


def saxpy_sct() -> SCT:
    k = kernel(lambda a, x, y: a * x + y, name="saxpy",
               inputs=[scalar("a"), vector("x", epu=1),
                       vector("y", epu=1)],
               outputs=[vector("z", epu=1)],
               flops_per_item=2.0, bytes_per_item=12.0)
    return Map(k)


def segmentation_sct(plane: int = 1024 * 1024) -> SCT:
    """3-D gray volume -> 3 classes; epu = one (D1 x D2) plane."""
    import jax.numpy as jnp
    k = kernel(lambda v: jnp.where(v < 85, 0.0,
                                   jnp.where(v > 170, 255.0, 128.0)),
               name="segmentation",
               inputs=[vector("vol", epu=1)],
               outputs=[vector("seg", epu=1)],
               flops_per_item=2.0 * plane, bytes_per_item=8.0 * plane)
    return Map(k)


#: name -> (sct builder(size), workload sizes, workload label) — the
#: paper's parameterisation classes (Table 2 / Table 3)
BENCHMARKS: Dict[str, Tuple] = {
    "filter_pipeline": (lambda n: filter_pipeline_sct(n),
                        [1024, 2048, 4096, 8192], "image size (px)"),
    "fft": (lambda n: fft_sct(),
            [256, 512, 1024], "#FFTs (512KiB each)"),
    "nbody": (lambda n: nbody_sct(n),
              [8192, 16384, 32768], "bodies"),
    "saxpy": (lambda n: saxpy_sct(),
              [10 ** 6, 10 ** 7, 5 * 10 ** 7], "elements"),
    "segmentation": (lambda n: segmentation_sct(),
                     [64, 512, 3840], "planes (1Mpx)"),
}


# ---------------------------------------------------------------------------
# Calibrated testbeds (paper hardware)
# ---------------------------------------------------------------------------

def opteron_testbed() -> List[SimDevice]:
    """Sec. 4.1: 4x Opteron 6272, 64 cores total, ~2.2 GHz."""
    return [SimDevice("cpu", "cpu", flops=280e9, mem_bw=51e9,
                      pcie_bw=math.inf, cores=64)]


def hybrid_testbed(n_gpus: int = 1) -> List[SimDevice]:
    """Sec. 4.2: i7-3930K + n x AMD HD 7950 (PCIe x16)."""
    devs = [SimDevice(f"gpu{i}", "gpu", flops=2870e9, mem_bw=240e9,
                      pcie_bw=8e9, cores=28) for i in range(n_gpus)]
    devs.append(SimDevice("cpu", "cpu", flops=150e9, mem_bw=43e9,
                          pcie_bw=math.inf, cores=6))
    return devs


def workload_for(name: str, size: int) -> Workload:
    if name == "filter_pipeline":
        return Workload((size, size))
    if name == "fft":
        return Workload((size, FFT_ELEMS), itemsize=8)
    if name == "nbody":
        return Workload((size, 4))
    if name == "segmentation":
        return Workload((size, 1024, 1024))
    return Workload((size,))


def cost_model_for(name: str, size: int) -> CostModel:
    """Per-domain-unit analytic costs (drives the simulator)."""
    w = workload_for(name, size)
    if name == "filter_pipeline":
        per_line = size
        return CostModel(flops_per_unit=9.0 * per_line,
                         bytes_per_unit=24.0 * per_line)
    if name == "fft":
        lg = math.log2(FFT_ELEMS)
        return CostModel(flops_per_unit=10 * FFT_ELEMS * lg,
                         bytes_per_unit=32.0 * FFT_ELEMS)
    if name == "nbody":
        return CostModel(flops_per_unit=20.0 * size, bytes_per_unit=32.0,
                         iterations=1.0)
    if name == "segmentation":
        return CostModel(flops_per_unit=2.0 * (1 << 20),
                         bytes_per_unit=8.0 * (1 << 20))
    return CostModel(flops_per_unit=2.0, bytes_per_unit=12.0)
