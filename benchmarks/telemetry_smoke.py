"""Telemetry smoke: fault-injected chain → validated Chrome trace.

CI gate for the observability subsystem.  Runs a 2-SCT ``run_chain``
with an injected gpu0 crash under a telemetry-enabled :class:`Session`,
then checks:

  * ``Session.export_trace`` writes a well-formed Chrome trace
    (``validate_chrome_trace``: required keys, matched B/E pairs);
  * the trace contains the plan, per-slot compute, retry (attempt > 0)
    and merge spans the span model promises;
  * ``Session.metrics()`` retry / plan-cache counters match the
    ``ExecutionStats`` the same runs returned;
  * a fault event and a repartition event were logged;
  * the disabled-telemetry path stays cheap (microbench bound, loose
    enough for shared CI runners).

The exported ``trace.json`` is uploaded as a CI artifact — drop it on
https://ui.perfetto.dev or ``chrome://tracing`` to inspect a run.

Run:  PYTHONPATH=src python benchmarks/telemetry_smoke.py [--out trace.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import (AcceleratorPlatform, DeviceInfo, FaultInjector,
                        FaultPolicy, HostPlatform, KnowledgeBase,
                        LoadBalancer, NULL_TELEMETRY, Scheduler, Session,
                        Telemetry, ThreadedExecutor, kernel, scalar, vector,
                        validate_chrome_trace)

try:
    from benchmarks.report import embed_metrics
except ImportError:                     # run as `python benchmarks/...`
    from report import embed_metrics

POLICY = FaultPolicy(watchdog_multiple=1e6)

# required by the span model (docs/observability.md); "attempt" spans with
# attempt >= 1 are the retry spans
REQUIRED_SPANS = {"run", "plan", "dispatch", "attempt", "slot", "merge"}


def chain_kernels():
    k1 = kernel(lambda a, x, y: a * x + y, name="saxpy",
                inputs=[scalar("a"), vector("x"), vector("y")],
                outputs=[vector("z")])
    k2 = kernel(lambda a, z: z * a, name="scale",
                inputs=[scalar("a"), vector("z")], outputs=[vector("w")])
    return [k1, k2]


def make_session(telemetry: Telemetry) -> Session:
    host = HostPlatform(DeviceInfo("cpu0", "cpu", compute_units=4),
                        topology={"L2": 2, "NO_FISSION": 1})
    accel = AcceleratorPlatform([DeviceInfo("gpu0", "gpu")], max_overlap=2)
    inj = FaultInjector(crash_on_call={"gpu0": [1]})
    ex = ThreadedExecutor(policy=POLICY, injector=inj)
    sched = Scheduler(host=host, accel=accel, executor=ex,
                      kb=KnowledgeBase(), balancer=LoadBalancer(max_dev=0.0))
    return Session(sched, telemetry=telemetry)


def noop_span_cost(iters: int = 50_000) -> float:
    """Seconds per disabled-telemetry span (shared no-op singleton)."""
    tracer = NULL_TELEMETRY.tracer
    t0 = time.perf_counter()
    for _ in range(iters):
        with tracer.span("x", device="gpu0"):
            pass
    return (time.perf_counter() - t0) / iters


def smoke(out: str) -> dict:
    failures = []
    telemetry = Telemetry()
    n = 1 << 14
    arrays = {"a": np.float32(2.0),
              "x": np.arange(n, dtype=np.float32),
              "y": np.ones(n, dtype=np.float32)}

    with make_session(telemetry) as session:
        runs = session.run_chain(chain_kernels(), **arrays).get()
        trace = session.export_trace(out)
        metrics = session.metrics()
        counters = session.counters()

    # -- trace well-formedness + span model ----------------------------------
    errors = validate_chrome_trace(trace)
    if errors:
        failures.append(f"trace validation: {errors[:5]}")
    names = {e["name"] for e in trace["traceEvents"]}
    missing = REQUIRED_SPANS - names
    if missing:
        failures.append(f"missing spans: {sorted(missing)}")
    retry_spans = [e for e in trace["traceEvents"]
                   if e["name"] == "attempt"
                   and e.get("args", {}).get("attempt", 0) >= 1]
    if not retry_spans:
        failures.append("no retry (attempt >= 1) span in the trace")

    # -- metrics vs ExecutionStats -------------------------------------------
    stats_retries = sum(r.stats.retries for r in runs)
    if stats_retries < 1:
        failures.append("fault injection did not exercise the retry path")
    if metrics.get("retries_total", 0) != stats_retries:
        failures.append(
            f"retries_total={metrics.get('retries_total')} != "
            f"sum(stats.retries)={stats_retries}")
    hits = metrics.get("plan_cache_hits_total", 0)
    misses = metrics.get("plan_cache_misses_total", 0)
    hit_ratio = hits / (hits + misses) if hits + misses else 0.0
    if abs(hit_ratio - counters["plan_cache.hit_rate"]) > 1e-9:
        failures.append(
            f"metrics hit ratio {hit_ratio} != plan-cache counter "
            f"{counters['plan_cache.hit_rate']}")

    # -- event stream --------------------------------------------------------
    kinds = {e.kind for e in telemetry.events.records()}
    for needed in ("fault", "retry.repartition"):
        if needed not in kinds:
            failures.append(f"missing event kind {needed!r}")

    # -- disabled-telemetry cost ---------------------------------------------
    cost = noop_span_cost()
    if cost > 20e-6:            # loose CI bound; tests enforce a tighter one
        failures.append(f"no-op span cost {cost * 1e6:.2f}µs > 20µs")

    result = {
        "bench": "telemetry_smoke",
        "trace_events": len(trace["traceEvents"]),
        "span_names": sorted(names),
        "retry_spans": len(retry_spans),
        "event_kinds": sorted(kinds),
        "stats_retries": stats_retries,
        "noop_span_cost_us": cost * 1e6,
        "failures": failures,
    }
    return embed_metrics(result, telemetry)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="trace.json",
                    help="Chrome trace output path")
    ap.add_argument("--json", default="BENCH_telemetry.json",
                    help="smoke-result JSON output path")
    args = ap.parse_args()

    result = smoke(args.out)
    with open(args.json, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps({k: v for k, v in result.items() if k != "metrics"},
                     indent=2))
    print(f"wrote {args.out} and {args.json}")
    for f in result["failures"]:
        print(f"SMOKE FAILED: {f}")
    raise SystemExit(1 if result["failures"] else 0)


if __name__ == "__main__":
    main()
