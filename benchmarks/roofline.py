"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads ``experiments/dryrun/<mesh>/*.json`` and renders, per (arch x
shape) cell: the three roofline terms, the dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS (remat/redundancy waste), and the roofline
fraction (the §Perf score).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

DRYRUN_DIR = "experiments/dryrun"


def load_cells(mesh: str = "single_pod_16x16",
               tag: Optional[str] = None) -> List[Dict]:
    pat = f"*--{tag}.json" if tag else "*.json"
    out = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, mesh, pat))):
        if tag is None and "--" in os.path.basename(p).replace(
                ".json", "").split("--", 1)[1]:
            # skip tagged (hillclimb) artifacts in the baseline table
            base = os.path.basename(p)[:-5]
            if base.count("--") > 1:
                continue
        with open(p) as f:
            out.append(json.load(f))
    return out


def render(mesh: str = "single_pod_16x16") -> List[str]:
    cells = load_cells(mesh)
    lines: List[str] = []
    print(f"== roofline ({mesh}) ==")
    hdr = (f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>9s} "
           f"{'coll_s':>8s} {'bneck':>7s} {'useful':>7s} {'frac':>7s} "
           f"{'mem/chip':>9s}")
    print(hdr)
    for d in cells:
        if d.get("skipped"):
            print(f"{d['arch']:22s} {d['shape']:12s} "
                  f"SKIP ({d['skipped'][:60]}...)")
            lines.append(f"roofline,{d['arch']},{d['shape']},skip")
            continue
        r = d["roofline"]
        m = d["memory"]
        print(f"{d['arch']:22s} {d['shape']:12s} {r['compute_s']:>10.4f} "
              f"{r['memory_s']:>9.4f} {r['collective_s']:>8.4f} "
              f"{r['bottleneck'][:7]:>7s} {r['useful_fraction']:>7.3f} "
              f"{r['roofline_fraction']:>7.4f} "
              f"{m['adjusted_peak_per_chip_bytes'] / 2**30:>8.2f}G")
        lines.append(
            f"roofline,{d['arch']},{d['shape']},{r['compute_s']:.5f},"
            f"{r['memory_s']:.5f},{r['collective_s']:.5f},"
            f"{r['bottleneck']},{r['roofline_fraction']:.5f}")
    return lines


def main(full: bool = False) -> List[str]:
    lines = []
    for mesh in ("single_pod_16x16", "multi_pod_2x16x16"):
        if os.path.isdir(os.path.join(DRYRUN_DIR, mesh)):
            lines.extend(render(mesh))
    if not lines:
        print("no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun` first")
    return lines


if __name__ == "__main__":
    main()
