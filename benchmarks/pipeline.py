"""Pipeline benchmark: graph-based concurrent submission (JobGraph).

Measures what the graph pipeline buys over the historical blocking
FCFS dispatch, in two deterministic virtual-time phases plus one
wall-clock phase:

  * **virtual throughput** — a fan-out JobGraph of K independent nodes
    with complementary device affinity (half pinned gpu-heavy, half
    cpu-heavy via KB profiles) on the :class:`SimulatedExecutor`,
    against the same K nodes forced into a serial chain (the FCFS
    order).  Virtual makespans are exact — no timer noise — so the
    speedup is CI-gated at the issue's >1.5x target.
  * **virtual overlap** — a 3-node fan-out whose spans must share a
    common instant (three nodes simultaneously in flight on the
    per-device work queues); CI-gated.
  * **threaded** — the same fan-out on the real ThreadedExecutor:
    bit-identical outputs vs. blocking sequential runs (gated), also
    under an injected per-node fault recovered by graph-level retry
    (gated), plus the wall-clock phase below.
  * **graph plan cache** — the same graph submitted twice: the second
    submission must be served from the whole-graph plan cache, with
    every node pre-planned and **zero decide/plan lock acquisitions**
    while it runs (gated), and bit-identical outputs (gated).
  * **fusion** — K identical single-node requests submitted
    concurrently with ``fusion_window`` set: they must coalesce into
    one fused run (one decide + dispatch + merge) whose slices are
    bit-identical to independently-run requests (gated), including
    under an injected fault recovered by in-run repartition (gated).
  * **wall throughput** (inside ``threaded``) — K identical small
    requests, serialized FCFS vs. concurrent admission with fusion.
    This is fusion's target regime — a high rate of small requests —
    and the ratio is **gated** (> 1.0 in full mode, a generous 0.4
    floor in --smoke for shared runners).  The PR-9 distinct-node
    fan-out ratio stays reported-only as ``wall_distinct_gain_x``: on
    a single-core runner concurrency alone cannot beat serialization,
    which is precisely why admission-side fusion exists.

Emits ``BENCH_pipeline.json`` (with an embedded telemetry metrics
block via ``benchmarks/report.embed_metrics``).

Run:  PYTHONPATH=src python benchmarks/pipeline.py [--smoke] [--check]
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import numpy as np

from repro.core import (AcceleratorPlatform, DeviceInfo, FaultInjector,
                        FaultPolicy, HostPlatform, JobGraph, KnowledgeBase,
                        LoadBalancer, Origin, PlatformConfig, Profile,
                        Scheduler, Session, Telemetry, ThreadedExecutor,
                        Workload, kernel, vector)
from repro.core.simulator import CostModel, SimDevice, SimulatedExecutor

try:
    from benchmarks.report import embed_metrics
except ImportError:                     # run as `python benchmarks/...`
    from report import embed_metrics

# a huge watchdog multiple disables spurious timeout trips on busy CI
POLICY = FaultPolicy(watchdog_multiple=1e6)


def node_kernel(i: int):
    """One independent graph node; distinct sct-id and output name."""
    c = np.float32(i + 1)
    return kernel(lambda x, y, c=c: x * c + y, name=f"node{i}",
                  inputs=[vector("x"), vector("y")],
                  outputs=[vector(f"o{i}")])


def make_arrays(n: int):
    return {"x": np.arange(n, dtype=np.float32),
            "y": np.ones(n, dtype=np.float32)}


def make_scheduler(executor, **kw) -> Scheduler:
    host = HostPlatform(DeviceInfo("cpu0", "cpu", compute_units=4),
                        topology={"L2": 2, "NO_FISSION": 1})
    accel = AcceleratorPlatform([DeviceInfo("gpu0", "gpu")], max_overlap=2)
    kw.setdefault("balancer", LoadBalancer(max_dev=0.0))
    kw.setdefault("kb", KnowledgeBase())
    return Scheduler(host=host, accel=accel, executor=executor, **kw)


def pin(sched: Scheduler, sct, n: int, share_a: float) -> None:
    sched.kb.store(Profile(
        sct_id=sct.unique_id(), workload=Workload((n,)), share_a=share_a,
        config=PlatformConfig(), best_time=float("inf"),
        origin=Origin.DERIVED))


# ---------------------------------------------------------------------------
# Virtual phases (deterministic — CI-gated)
# ---------------------------------------------------------------------------

def virtual_scheduler(*, symmetric: bool) -> Scheduler:
    """Simulator whose compute dwarfs per-slot dispatch overhead.

    ``symmetric`` gives the CPU the GPU's throughput, so a gpu-heavy
    and a cpu-heavy node have equal makespans and the two device work
    queues carry equal totals — the ideal pipelining scenario."""
    devs = [SimDevice("gpu0", "gpu", flops=1e12),
            SimDevice("cpu0", "cpu", flops=1e12 if symmetric else 1e11,
                      cores=4)]
    sim = SimulatedExecutor(devs, noise=0.0,
                            cost=CostModel(flops_per_unit=1e6,
                                           bytes_per_unit=0.0))
    return make_scheduler(sim)


def graph_makespan(handle) -> float:
    spans = handle.spans().values()
    return (max(e for _, e in spans) - min(s for s, _ in spans)) / 1e6


def bench_virtual_throughput(n: int, k: int) -> dict:
    """Fan-out of K complementary nodes vs. the same nodes serialised."""
    scts = [node_kernel(i) for i in range(k)]
    shares = [0.95 if i % 2 == 0 else 0.05 for i in range(k)]

    # serialized FCFS: a linear chain forces one-at-a-time execution
    serial = virtual_scheduler(symmetric=True)
    g_serial = JobGraph()
    prev = ()
    for sct, sh in zip(scts, shares):
        pin(serial, sct, n, sh)
        prev = (g_serial.add(sct, after=prev),)
    t_serial = graph_makespan(serial.submit(g_serial, make_arrays(n)))

    # concurrent: the same nodes as a pure fan-out through the Session
    conc = virtual_scheduler(symmetric=True)
    g_conc = JobGraph()
    for sct, sh in zip(scts, shares):
        pin(conc, sct, n, sh)
        g_conc.add(sct)
    with Session(conc) as sess:
        t_conc = graph_makespan(sess.submit(g_conc, **make_arrays(n)))

    return {"nodes": k, "serialized_makespan_s": t_serial,
            "concurrent_makespan_s": t_conc,
            "throughput_gain_x": t_serial / t_conc if t_conc > 0 else 0.0}


def bench_virtual_overlap(n: int) -> dict:
    """Three cpu-heavy nodes: short gpu legs drain while long cpu legs
    run, so all three nodes are in flight at one instant."""
    scts = [node_kernel(i) for i in range(3)]
    sched = virtual_scheduler(symmetric=False)
    g = JobGraph()
    for sct in scts:
        pin(sched, sct, n, 0.1)
        g.add(sct)
    with Session(sched) as sess:
        handle = sess.submit(g, **make_arrays(n))
    spans = list(handle.spans().values())
    max_conc = max(sum(1 for (s, e) in spans if s <= t < e)
                   for (t, _) in spans)
    return {"nodes": 3, "spans_us": sorted(spans),
            "max_concurrent_nodes": max_conc}


# ---------------------------------------------------------------------------
# Threaded phase (bit-identity gated; wall throughput reported)
# ---------------------------------------------------------------------------

def bench_threaded(n: int, k: int, reps: int, telemetry) -> dict:
    scts = [node_kernel(i) for i in range(k)]
    arrays = make_arrays(n)

    # blocking FCFS baseline: one sched.run per node, in order
    seq = make_scheduler(ThreadedExecutor(policy=POLICY))
    expected = {}
    for sct in scts:
        r = seq.run(sct, dict(arrays))
        expected.update({kk: np.copy(np.asarray(v))
                         for kk, v in r.outputs.items()})
    seq.close()

    # concurrent graph execution — bit-identity gate
    par = make_scheduler(ThreadedExecutor(policy=POLICY),
                         telemetry=telemetry)
    g = JobGraph()
    for sct in scts:
        g.add(sct)
    res = par.submit(g, arrays).result(timeout=120)
    bit_identical = all(
        np.array_equal(expected[kk], np.asarray(res.outputs[kk]))
        for kk in expected)
    par.close()

    # fault-injected per-node retry — bit-identity under recovery
    inj = FaultInjector(crash_on_call={"gpu0": [1]})
    flt = make_scheduler(
        ThreadedExecutor(injector=inj, policy=FaultPolicy(
            max_attempts=1, watchdog_multiple=1e6)),
        telemetry=telemetry)
    g2 = JobGraph()
    for sct in scts:
        g2.add(sct)
    res2 = flt.submit(g2, arrays, retries=2,
                      retry_backoff=0.01).result(timeout=120)
    bit_identical_faulted = all(
        np.array_equal(expected[kk], np.asarray(res2.outputs[kk]))
        for kk in expected)
    node_retries = int(flt.counters()["scheduler.failed_runs"])
    flt.close()

    # distinct-node fan-out wall ratio (reported only, see module doc)
    def timed_distinct(max_inflight: int) -> float:
        sched = make_scheduler(ThreadedExecutor(policy=POLICY),
                               max_inflight=max(2, max_inflight))
        with Session(sched, max_inflight=max_inflight) as sess:
            def round_():
                handles = []
                for sct in scts:
                    gr = JobGraph()
                    gr.add(sct)
                    handles.append(sess.submit(gr, **arrays))
                sess.gather(*handles, timeout=120)
            round_()                    # warm pools, caches, KB
            t0 = time.perf_counter()
            round_()
            return time.perf_counter() - t0

    d_serial = statistics.median(timed_distinct(1) for _ in range(reps))
    d_conc = statistics.median(timed_distinct(k) for _ in range(reps))

    # gated wall throughput: K identical small requests — serialized
    # FCFS vs. concurrent admission coalesced by cross-request fusion
    # into a single decide + dispatch + merge
    n_small, k_ident = WALL_N, WALL_K
    sct_i = node_kernel(0)
    small = make_arrays(n_small)

    def timed_identical(max_inflight: int, fusion_window: float) -> float:
        sched = make_scheduler(ThreadedExecutor(policy=POLICY),
                               max_inflight=max(2, max_inflight),
                               fusion_window=fusion_window,
                               fusion_max=k_ident)
        with Session(sched, max_inflight=max_inflight) as sess:
            def round_():
                handles = [sess.submit(JobGraph.from_chain([sct_i]), **small)
                           for _ in range(k_ident)]
                sess.gather(*handles, timeout=120)
            round_()                    # warm pools, plan caches, KB
            t0 = time.perf_counter()
            round_()
            return time.perf_counter() - t0

    wall_reps = max(reps, 5)    # cheap rounds; medians need the depth
    serialized = statistics.median(
        timed_identical(1, 0.0) for _ in range(wall_reps))
    concurrent = statistics.median(
        timed_identical(k_ident, 0.5) for _ in range(wall_reps))

    return {"nodes": k, "bit_identical": bit_identical,
            "bit_identical_faulted": bit_identical_faulted,
            "node_retries": node_retries,
            "distinct_serialized_wall_s": d_serial,
            "distinct_concurrent_wall_s": d_conc,
            "wall_distinct_gain_x": d_serial / d_conc if d_conc > 0 else 0.0,
            "wall_n": n_small, "wall_requests": k_ident,
            "serialized_wall_s": serialized,
            "concurrent_wall_s": concurrent,
            "wall_throughput_gain_x": (serialized / concurrent
                                       if concurrent > 0 else 0.0)}


# ---------------------------------------------------------------------------
# Graph plan cache + fusion phases (gated)
# ---------------------------------------------------------------------------

WALL_N = 1 << 16        # fusion's target regime: many small requests
WALL_K = 8


def bench_graph_plan_cache(n: int, k: int, telemetry) -> dict:
    """Identical graph submitted twice: the second submission must be
    pre-planned end to end — a whole-graph cache hit, every node action
    ``preplanned``, zero decide/plan lock acquisitions."""
    scts = [node_kernel(i) for i in range(k)]
    arrays = make_arrays(n)
    sched = make_scheduler(ThreadedExecutor(policy=POLICY),
                           telemetry=telemetry)

    def submit_once():
        g = JobGraph()
        for sct in scts:
            g.add(sct)
        return sched.submit(g, arrays).result(timeout=120)

    r1 = submit_once()
    c0 = sched.counters()
    r2 = submit_once()
    c1 = sched.counters()
    sched.close()
    return {
        "nodes": k,
        "graph_hits": int(c1["plan_cache.graph_hits"]),
        "graph_misses": int(c1["plan_cache.graph_misses"]),
        "decide_locks_second": int(c1["scheduler.decide_locks"]
                                   - c0["scheduler.decide_locks"]),
        "plan_locks_second": int(c1["scheduler.plan_locks"]
                                 - c0["scheduler.plan_locks"]),
        "preplanned_nodes": sum(1 for r in r2.runs.values()
                                if r.action == "preplanned"),
        "bit_identical": all(
            np.array_equal(np.asarray(r1.outputs[kk]),
                           np.asarray(r2.outputs[kk]))
            for kk in r1.outputs),
    }


def bench_fused(telemetry) -> dict:
    """K identical requests (distinct array *values*) coalesced by the
    fusion window: slices must be bit-identical to independent runs —
    clean, and under an injected fault recovered by in-run
    repartition."""
    n, k = WALL_N, WALL_K
    sct = node_kernel(0)
    batches = [{"x": np.arange(n, dtype=np.float32) + i,
                "y": np.full(n, float(i + 1), dtype=np.float32)}
               for i in range(k)]

    # independent baseline: one ordinary run per request
    base = make_scheduler(ThreadedExecutor(policy=POLICY))
    expected = [np.copy(np.asarray(base.run(sct, dict(b)).outputs["o0"]))
                for b in batches]
    base.close()

    def fused_outputs(injector=None):
        sched = make_scheduler(
            ThreadedExecutor(policy=POLICY, injector=injector),
            telemetry=telemetry, max_inflight=2,
            fusion_window=0.5, fusion_max=k)
        with Session(sched, max_inflight=k) as sess:
            handles = [sess.submit(JobGraph.from_chain([sct]), **b)
                       for b in batches]
            results = sess.gather(*handles, timeout=120)
        got = [np.copy(np.asarray(r.outputs["o0"])) for r in results]
        retries = int(sched.counters()["scheduler.retries"])
        actions = [r.runs[list(r.runs)[0]].action for r in results]
        sched.close()
        return got, retries, actions

    got, _, actions = fused_outputs()
    clean = all(np.array_equal(e, g) for e, g in zip(expected, got))

    inj = FaultInjector(crash_on_call={"gpu0": [1]})
    got_f, retries_f, _ = fused_outputs(injector=inj)
    faulted = all(np.array_equal(e, g) for e, g in zip(expected, got_f))

    return {"requests": k, "n": n,
            "fused_actions": sum(1 for a in actions if a == "fused"),
            "bit_identical": clean,
            "bit_identical_faulted": faulted,
            "fused_run_retries": retries_f}


# ---------------------------------------------------------------------------

def bench(smoke: bool) -> dict:
    telemetry = Telemetry()
    result = {
        "bench": "pipeline", "smoke": smoke, "n": ARGS.n,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "virtual_throughput": bench_virtual_throughput(4096, k=6),
        "virtual_overlap": bench_virtual_overlap(4096),
        "threaded": bench_threaded(ARGS.n, k=4,
                                   reps=3 if smoke else 7,
                                   telemetry=telemetry),
        "graph_plan_cache": bench_graph_plan_cache(ARGS.n, k=4,
                                                   telemetry=telemetry),
        "fusion": bench_fused(telemetry=telemetry),
    }
    return embed_metrics(result, telemetry)


def check(result) -> int:
    failures = []
    smoke = bool(result.get("smoke"))
    gain = result["virtual_throughput"]["throughput_gain_x"]
    if gain <= 1.5:
        failures.append(
            f"virtual concurrent throughput gain {gain:.2f}x <= 1.5x")
    conc = result["virtual_overlap"]["max_concurrent_nodes"]
    if conc < 3:
        failures.append(
            f"only {conc} nodes simultaneously in flight (need >= 3)")
    if not result["threaded"]["bit_identical"]:
        failures.append("graph outputs differ from blocking FCFS runs")
    if not result["threaded"]["bit_identical_faulted"]:
        failures.append("fault-injected graph outputs differ from FCFS")
    if result["threaded"]["node_retries"] < 1:
        failures.append("fault injection did not exercise per-node retry")

    # whole-graph plan cache: second identical submission is a hit and
    # runs without a single decide/plan lock acquisition
    gpc = result["graph_plan_cache"]
    if gpc["graph_hits"] < 1:
        failures.append("second identical submission missed the "
                        "graph plan cache")
    if gpc["decide_locks_second"] != 0 or gpc["plan_locks_second"] != 0:
        failures.append(
            f"pre-planned submission acquired locks (decide="
            f"{gpc['decide_locks_second']}, plan="
            f"{gpc['plan_locks_second']}; need 0/0)")
    if gpc["preplanned_nodes"] != gpc["nodes"]:
        failures.append(
            f"only {gpc['preplanned_nodes']}/{gpc['nodes']} nodes ran "
            "pre-planned on the cached submission")
    if not gpc["bit_identical"]:
        failures.append("pre-planned outputs differ from first run")

    # cross-request fusion: coalesced slices bit-identical to
    # independent runs, with and without an injected fault
    fus = result["fusion"]
    if fus["fused_actions"] != fus["requests"]:
        failures.append(
            f"only {fus['fused_actions']}/{fus['requests']} requests "
            "were served from the fused run")
    if not fus["bit_identical"]:
        failures.append("fused request slices differ from independent runs")
    if not fus["bit_identical_faulted"]:
        failures.append("fault-injected fused slices differ from "
                        "independent runs")
    if fus["fused_run_retries"] < 1:
        failures.append("fault injection did not exercise the fused "
                        "run's repartition retry")

    # wall throughput: fusion must make concurrent admission of
    # identical requests beat serialized FCFS (generous smoke floor
    # for shared runners)
    floor = 0.4 if smoke else 1.0
    wall = result["threaded"]["wall_throughput_gain_x"]
    if wall <= floor:
        failures.append(
            f"wall throughput gain {wall:.2f}x <= {floor}x "
            f"({'smoke floor' if smoke else 'full gate'})")

    for f in failures:
        print(f"CHECK FAILED: {f}")
    return 1 if failures else 0


def main():
    global ARGS
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small workload / few reps (CI)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero if acceptance gates regress")
    ap.add_argument("--out", default="BENCH_pipeline.json")
    ap.add_argument("--n", type=int, default=None,
                    help="vector length (default: 1<<18 smoke, 1<<20 full)")
    ARGS = ap.parse_args()
    if ARGS.n is None:
        ARGS.n = (1 << 18) if ARGS.smoke else (1 << 20)

    result = bench(ARGS.smoke)
    with open(ARGS.out, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))
    print(f"wrote {ARGS.out}")
    if ARGS.check:
        raise SystemExit(check(result))


if __name__ == "__main__":
    main()
