"""Table 5 / Figs 9-10 — KB configuration derivation vs profile
construction.

Protocol (paper Sec. 4.2.2): independently build a profile for each of 8
image sizes (the baselines); then, starting from a KB holding only
Image 0's profile, process Images 1..7 via *derivation only* — measuring
the derived-distribution error, the performance error, the number of
unbalanced executions (of 100) and load-balance operations.  Paper
claims: distribution error < 3%, performance error < 5% after the first
three images, balancer fires < 4 times per 100 in steady state.
"""
from __future__ import annotations

import math
from typing import Dict, List

from benchmarks.hybrid import make_scheduler
from benchmarks.paper_suite import BENCHMARKS, workload_for
from repro.core import KnowledgeBase, LoadBalancer, TunerParams, \
    build_profile
from repro.core.distribution import Distribution
from repro.core.knowledge_base import Origin, PlatformConfig, Profile
from repro.core.load_balancer import class_times
from repro.core.spec import Workload

#: the paper's image sequence (Table 5)
IMAGES = [1024, 4288, 512, 8192, 1800, 2048, 256, 1440]


def _evaluator(sched, sct, workload, arrays):
    def evaluate(cfg: PlatformConfig, dist: Distribution):
        prof = Profile(sct_id=sct.unique_id(), workload=workload,
                       share_a=dist.a, config=cfg, best_time=math.inf)
        _, stats, _, _, _ = sched._dispatch(sct, arrays, prof)
        n_a = sum(1 for s in sched._slots(prof) if s.device_type != "cpu")
        ta, tb = class_times(stats.times, n_a)
        return stats.total, ta, tb
    return evaluate


def build_baseline(size: int) -> Profile:
    sct = BENCHMARKS["filter_pipeline"][0](size)
    workload = Workload((size, size))
    sched, sim = make_scheduler("filter_pipeline", size, n_gpus=1)
    arrays = sim.synthesise_arrays(sct, workload)
    res = build_profile(sct.unique_id(), workload, host=sched.host,
                        accel=sched.accel,
                        evaluate=_evaluator(sched, sct, workload, arrays),
                        params=TunerParams(number_executions=1))
    return res.profile


def main(full: bool = False) -> List[str]:
    runs = 100 if full else 30
    print("== KB derivation vs construction (Table 5 / Figs 9-10) ==")
    baselines: Dict[int, Profile] = {}
    sizes = IMAGES if full else IMAGES[:5]
    for size in sizes:
        baselines[size] = build_baseline(size)

    kb = KnowledgeBase()
    kb.store(baselines[sizes[0]])
    lines: List[str] = []
    print(f"{'image':>6s} {'built gpu%':>10s} {'derived gpu%':>12s} "
          f"{'dist err%':>9s} {'perf err%':>9s} {'unbal':>6s} {'ops':>4s}")
    for size in sizes[1:]:
        sct = BENCHMARKS["filter_pipeline"][0](size)
        workload = Workload((size, size))
        sched, sim = make_scheduler("filter_pipeline", size, n_gpus=1)
        sched.kb = kb
        arrays = sim.synthesise_arrays(sct, workload)
        derived = kb.derive(sct.unique_id(), workload)
        base = baselines[size]
        dist_err = abs(derived.share_a - base.share_a) * 100

        # run 100 executions with balancing, as the paper does
        balancer = LoadBalancer(max_dev=0.85)
        cur = derived
        unbalanced = ops = 0
        best_time = math.inf
        for _ in range(runs):
            _, stats, _, _, _ = sched._dispatch(sct, arrays, cur)
            best_time = min(best_time, stats.total)
            if balancer.is_unbalanced(stats.deviation):
                unbalanced += 1
            if balancer.observe(stats):
                n_a = sum(1 for s in sched._slots(cur)
                          if s.device_type != "cpu")
                ta, tb = class_times(stats.times, n_a)
                new = balancer.adjust(
                    Distribution(a=cur.share_a, b=1 - cur.share_a), ta, tb)
                cur = Profile(sct_id=cur.sct_id, workload=workload,
                              share_a=new.a, config=cur.config,
                              best_time=math.inf, origin=Origin.DERIVED)
                ops += 1
                balancer.lbt = 0.0
        kb.store(Profile(sct_id=cur.sct_id, workload=workload,
                         share_a=cur.share_a, config=cur.config,
                         best_time=best_time, origin=Origin.DERIVED))
        perf_err = (best_time - base.best_time) / base.best_time * 100
        print(f"{size:>6d} {100 * base.share_a:>10.1f} "
              f"{100 * derived.share_a:>12.1f} {dist_err:>9.2f} "
              f"{perf_err:>9.2f} {unbalanced:>6d} {ops:>4d}")
        lines.append(f"kb_derivation,{size},{dist_err:.2f},"
                     f"{perf_err:.2f},{unbalanced},{ops}")
    return lines


if __name__ == "__main__":
    main(full=True)
