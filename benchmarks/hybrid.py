"""Table 3 / Figs 7-8 — CPU+GPU versus GPU-only executions.

For each paper benchmark x parameterisation class x (1 GPU, 2 GPUs):
run Algorithm 1 (profile construction) on the calibrated hybrid testbed,
then compare the tuned hybrid execution to the GPU-only baseline.
Paper claims: hybrid speedup 1.11-2.07x (avg 1.72x) on 1 GPU and
1.00-1.88x (avg 1.56x) on 2 GPUs; NBody stays GPU-only; the CPU share
shrinks as GPUs are added.
"""
from __future__ import annotations

import math
from typing import Dict, List, Tuple

from benchmarks.paper_suite import (BENCHMARKS, cost_model_for,
                                    hybrid_testbed, workload_for)
from repro.core import (AcceleratorPlatform, DeviceInfo, HostPlatform,
                        KnowledgeBase, TunerParams, build_profile)
from repro.core.distribution import Distribution
from repro.core.knowledge_base import PlatformConfig, Profile
from repro.core.simulator import SimulatedExecutor
from repro.core.scheduler import Scheduler

I7_TOPOLOGY = {"L1": 6, "L2": 6, "L3": 2, "NO_FISSION": 1}

CLASSES = {
    "filter_pipeline": [2048, 4096, 8192],
    "fft": [128, 256, 512],
    "nbody": [16384, 32768, 65536],
    "saxpy": [10 ** 6, 10 ** 7, 10 ** 8],
    "segmentation": [64, 512, 3840],
}


def make_scheduler(name: str, size: int, n_gpus: int):
    host = HostPlatform(DeviceInfo("cpu", "cpu", compute_units=6),
                        topology=I7_TOPOLOGY)
    accel = AcceleratorPlatform(
        [DeviceInfo(f"gpu{i}", "gpu", peak_flops=2.87e12)
         for i in range(n_gpus)], max_overlap=4)
    sim = SimulatedExecutor(hybrid_testbed(n_gpus), seed=1,
                            cost=cost_model_for(name, size))
    sched = Scheduler(host=host, accel=accel, executor=sim,
                      kb=KnowledgeBase())
    return sched, sim


def tune_cell(name: str, size: int, n_gpus: int) -> Dict:
    sct = BENCHMARKS[name][0](size)
    workload = workload_for(name, size)
    sched, sim = make_scheduler(name, size, n_gpus)
    arrays = sim.synthesise_arrays(sct, workload)

    def evaluate(cfg: PlatformConfig, dist: Distribution):
        prof = Profile(sct_id=sct.unique_id(), workload=workload,
                       share_a=dist.a, config=cfg, best_time=math.inf)
        _, stats, _, _, _ = sched._dispatch(sct, arrays, prof)
        n_a = sum(1 for s in sched._slots(prof)
                  if s.device_type != "cpu")
        ta = max(stats.times[:n_a]) if n_a else 0.0
        tb = max(stats.times[n_a:]) if len(stats.times) > n_a else 0.0
        return stats.total, ta, tb

    res = build_profile(sct.unique_id(), workload, host=sched.host,
                        accel=sched.accel, evaluate=evaluate,
                        params=TunerParams(number_executions=1,
                                           precision=1e-4))
    # GPU-only baseline: share_a = 1, best overlap from the same tuner cfg
    base_prof = Profile(sct_id=sct.unique_id(), workload=workload,
                        share_a=1.0,
                        config=PlatformConfig(
                            fission_level="NO_FISSION",
                            overlap=res.profile.config.overlap))
    _, base_stats, _, _, _ = sched._dispatch(sct, arrays, base_prof)
    return {"benchmark": name, "size": size, "gpus": n_gpus,
            "hybrid_time": res.profile.best_time,
            "gpu_only_time": base_stats.total,
            "speedup": base_stats.total / max(res.profile.best_time, 1e-12),
            "gpu_share": res.profile.share_a,
            "fission": res.profile.config.fission_level,
            "overlap": res.profile.config.overlap,
            "evals": res.evaluations}


def main(full: bool = False) -> List[str]:
    lines: List[str] = []
    print("== hybrid CPU+GPU vs GPU-only (Table 3 / Figs 7-8) ==")
    print(f"{'benchmark':18s} {'size':>9s} {'gpus':>4s} {'speedup':>8s} "
          f"{'gpu share':>9s} {'fission':>9s} {'overlap':>7s}")
    shares = {1: [], 2: []}
    speeds = {1: [], 2: []}
    for name, sizes in CLASSES.items():
        use = sizes if full else sizes[1:2]
        for size in use:
            for n_gpus in (1, 2):
                r = tune_cell(name, size, n_gpus)
                print(f"{name:18s} {size:>9d} {n_gpus:>4d} "
                      f"{r['speedup']:>8.2f} {r['gpu_share']:>9.2f} "
                      f"{r['fission']:>9s} {r['overlap']:>7d}")
                lines.append(
                    f"hybrid,{name},{size},{n_gpus},{r['speedup']:.3f},"
                    f"{r['gpu_share']:.3f}")
                shares[n_gpus].append(r["gpu_share"])
                speeds[n_gpus].append(r["speedup"])
    for g in (1, 2):
        if speeds[g]:
            avg = sum(speeds[g]) / len(speeds[g])
            print(f"  avg hybrid speedup {g} GPU(s): {avg:.2f}x "
                  f"(paper: {1.72 if g == 1 else 1.56:.2f}x)")
            lines.append(f"hybrid_avg,{g}gpu,{avg:.3f}")
    if shares[1] and shares[2]:
        s1 = sum(shares[1]) / len(shares[1])
        s2 = sum(shares[2]) / len(shares[2])
        print(f"  avg CPU share: {1 - s1:.2f} (1 GPU) -> {1 - s2:.2f} "
              f"(2 GPUs)  [paper: decreases]")
        lines.append(f"hybrid_cpu_share,{1 - s1:.3f},{1 - s2:.3f}")
    return lines


if __name__ == "__main__":
    main(full=True)
