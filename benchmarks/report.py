"""Regenerate the EXPERIMENTS.md tables from dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import glob
import json
import os
import re

GiB = 2 ** 30


def embed_metrics(result: dict, telemetry) -> dict:
    """Embed a telemetry metrics snapshot into a BENCH_*.json result.

    Every benchmark that runs under a telemetry-enabled scheduler calls
    this before dumping its JSON, so artifacts carry the counters
    (plan-cache hit ratio, retries, per-device busy seconds, ...) that
    produced the headline numbers.  ``telemetry`` is a
    ``repro.core.telemetry.Telemetry``; the import is lazy so this
    module stays usable without ``PYTHONPATH=src``.
    """
    from repro.core.telemetry import metrics_block
    result["metrics"] = metrics_block(telemetry)
    return result


def load(mesh):
    cells = {}
    for p in sorted(glob.glob(f"experiments/dryrun/{mesh}/*.json")):
        base = os.path.basename(p)[:-5]
        if base.count("--") > 1:
            continue                      # hillclimb variants
        d = json.load(open(p))
        cells[(d["arch"], d["shape"])] = d
    return cells


def dryrun_summary() -> str:
    out = ["", "| mesh | cells compiled | skips (assignment) | over 16 GiB |",
           "|---|---|---|---|"]
    for mesh in ("single_pod_16x16", "multi_pod_2x16x16"):
        cells = load(mesh)
        comp = [d for d in cells.values() if not d.get("skipped")]
        skip = [d for d in cells.values() if d.get("skipped")]
        over = [d for d in comp if not d["memory"]["fits_16GiB"]]
        out.append(f"| {mesh} | {len(comp)} | {len(skip)} | {len(over)} |")
    out += ["",
            "Per-cell compile seconds, per-chip memory analysis, HLO "
            "FLOPs/bytes/collectives and the roofline record are in "
            "`experiments/dryrun/<mesh>/<arch>--<shape>.json`.",
            ""]
    return "\n".join(out)


def roofline_table() -> str:
    rows = ["",
            "All terms in seconds per step on TPU v5e (197 TF bf16, "
            "819 GB/s HBM, 50 GB/s/link); `useful` = MODEL_FLOPS / "
            "HLO_FLOPS; `frac` = roofline fraction (the §Perf score); "
            "`mem` = adjusted peak per chip (DESIGN.md §9.6).",
            "",
            "### single-pod 16x16 (256 chips)", "",
            "| arch | shape | compute_s | memory_s | collective_s | "
            "bottleneck | useful | frac | mem/chip |",
            "|---|---|---|---|---|---|---|---|---|"]
    cells = load("single_pod_16x16")
    for (arch, shape), d in sorted(cells.items()):
        if d.get("skipped"):
            rows.append(f"| {arch} | {shape} | — | — | — | skip "
                        f"(sub-quadratic rule) | — | — | — |")
            continue
        r = d["roofline"]
        m = d["memory"]
        rows.append(
            f"| {arch} | {shape} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['bottleneck']} | {r['useful_fraction']:.3f} | "
            f"{r['roofline_fraction']:.4f} | "
            f"{m['adjusted_peak_per_chip_bytes'] / GiB:.2f} GiB |")
    rows += ["", "### multi-pod 2x16x16 (512 chips) — compile gate", "",
             "| arch | shape | compiles | frac | mem/chip |",
             "|---|---|---|---|---|"]
    for (arch, shape), d in sorted(load("multi_pod_2x16x16").items()):
        if d.get("skipped"):
            rows.append(f"| {arch} | {shape} | skip | — | — |")
            continue
        r, m = d["roofline"], d["memory"]
        rows.append(f"| {arch} | {shape} | yes | "
                    f"{r['roofline_fraction']:.4f} | "
                    f"{m['adjusted_peak_per_chip_bytes'] / GiB:.2f} GiB |")
    rows.append("")
    return "\n".join(rows)


def inject(md_path="EXPERIMENTS.md"):
    text = open(md_path).read()
    text = re.sub(
        r"<!-- DRYRUN_SUMMARY -->.*?(?=## §Roofline)",
        "<!-- DRYRUN_SUMMARY -->\n" + dryrun_summary() + "\n",
        text, flags=re.S)
    text = re.sub(
        r"<!-- ROOFLINE_TABLE -->.*?(?=## §Perf)",
        "<!-- ROOFLINE_TABLE -->\n" + roofline_table() + "\n",
        text, flags=re.S)
    open(md_path, "w").write(text)
    print(f"updated {md_path}")


if __name__ == "__main__":
    inject()
